"""Config system: model/shape/mesh/run dataclasses shared by every layer.

Every assigned architecture is expressed as a ``ModelConfig``; the dry-run,
trainer, server, benchmarks and tests all consume the same object.  Reduced
("smoke") variants are derived mechanically so smoke tests always exercise the
same code path as the full config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts sub-config (Lina's subject matter)."""

    n_experts: int = 0
    top_k: int = 0
    d_ff: int = 0                 # expert hidden size
    every: int = 1                # MoE layer every `every`-th block
    shared_expert: bool = False   # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # Lina knobs
    n_microops: int = 4           # a2a tensor-partition count (micro-ops)
    pipeline_ffn: bool = True     # pipeline expert FFN with a2a micro-ops
    # ScMoE-style shortcut connection: the dense (shared-expert) branch is
    # computed *inside* the MoE shard body, ordered under the dispatch-a2a
    # shadow, and summed into the combine.  Requires shared weights (the
    # model allocates them when shortcut is set, like shared_expert).
    shortcut: bool = False
    experts_per_device: int = 1   # expert packing degree (power of two)
    # compute backend for the MoE hot paths (gating / grouped FFN / the
    # serving slot compute): "pallas" routes through repro.kernels.ops,
    # "xla" keeps the einsum path, "auto" picks pallas on TPU and xla
    # elsewhere (kernels.ops.resolve_backend).
    compute_backend: str = "auto"

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2/RWKV6 state-space sub-config."""

    d_state: int = 0
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128              # chunked-scan block length

    @property
    def enabled(self) -> bool:
        return self.d_state > 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 => attention-free
    n_kv_heads: int
    d_ff: int                     # dense FFN hidden size
    vocab_size: int

    head_dim: int = 0             # 0 => d_model // n_heads
    ffn_type: str = "swiglu"      # swiglu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0       # 0 => full attention
    rope_theta: float = 10_000.0
    causal: bool = True           # False => encoder-only (no decode shapes)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # layer pattern for hybrids: 'M' mamba2, 'A' attention, '*' attention
    # with *shared* weights (zamba2); empty => uniform attention stack.
    layer_pattern: str = ""

    # modality frontend: none | vision_stub | audio_stub.  Stub frontends
    # receive precomputed patch/frame embeddings via input_specs().
    frontend: str = "none"
    n_patches: int = 0            # vision stub: patches prepended to the text

    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # optimizer-master dtype
    opt_state_dtype: str = "float32"
    remat: bool = True
    # sequence parallelism: shard the inter-block activations (and the saved
    # scan carry) over `model` — Megatron-SP; OFF for the paper-faithful
    # baseline, toggled in §Perf hillclimbs.
    seq_parallel: bool = False
    # tensor parallelism over `model`; False = pure DP/FSDP across all mesh
    # axes (the right choice for small models — §Perf hillclimb)
    tensor_parallel: bool = True

    notes: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def n_moe_layers(self) -> int:
        if not self.moe.enabled:
            return 0
        return self.n_layers // self.moe.every

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        ffn_mult = 3 if self.ffn_type == "swiglu" else 2
        attn = (self.n_heads * hd * d) * 2 + (self.n_kv_heads * hd * d) * 2
        n_attn_layers = self.n_layers
        if self.layer_pattern:
            pat = self._resolved_pattern()
            n_attn_layers = pat.count("A")
            shared = 1 if "*" in pat else 0
            n_mamba = pat.count("M") + pat.count("*") if self.ssm.enabled else 0
            # zamba2: '*' layers are mamba layers that also run the shared block
            n_mamba = pat.count("M") + pat.count("*")
            d_in = d * self.ssm.expand
            per_mamba = d * (2 * d_in + 2 * self.ssm.d_state) + d_in * d + 3 * d_in
            total += n_mamba * per_mamba
            total += shared * (attn + ffn_mult * d * f)
            total += n_attn_layers * (attn + ffn_mult * d * f)
        elif self.attention_free and self.ssm.enabled:
            # rwkv6: time-mix (~5 d^2 square mats + decay MLPs) + channel mix
            total += self.n_layers * (5 * d * d + 2 * d * f + d * f)
        else:
            total += n_attn_layers * attn
            n_moe = self.n_moe_layers
            n_dense = self.n_layers - n_moe
            total += n_dense * ffn_mult * d * f
            if self.moe.enabled:
                e_f = self.moe.d_ff or f
                per_expert = ffn_mult * d * e_f
                total += n_moe * self.moe.n_experts * per_expert
                if self.moe.shared_expert or self.moe.shortcut:
                    total += n_moe * per_expert
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top_k experts count)."""
        if not self.moe.enabled:
            return self.param_count()
        full = self.param_count()
        e_f = self.moe.d_ff or self.d_ff
        ffn_mult = 3 if self.ffn_type == "swiglu" else 2
        per_expert = ffn_mult * self.d_model * e_f
        inactive = self.n_moe_layers * (self.moe.n_experts - self.moe.top_k) * per_expert
        return int(full - inactive)

    def _resolved_pattern(self) -> str:
        return self.layer_pattern

    def smoke(self) -> "ModelConfig":
        """Mechanically reduced config of the same family for CPU tests."""
        moe = self.moe
        if moe.enabled:
            moe = replace(moe, n_experts=min(moe.n_experts, 4),
                          top_k=min(moe.top_k, 2),
                          d_ff=min(moe.d_ff or 64, 64))
        ssm = self.ssm
        if ssm.enabled:
            ssm = replace(ssm, d_state=min(ssm.d_state, 16), head_dim=16,
                          chunk=16)
        n_layers = min(self.n_layers, 4 if not self.layer_pattern else 7)
        pat = self.layer_pattern[:n_layers] if self.layer_pattern else ""
        if pat and "*" not in pat and "*" in self.layer_pattern:
            pat = pat[:-1] + "*"
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if n_heads else 0
        if self.n_kv_heads == self.n_heads:
            n_kv = n_heads  # keep MHA archs MHA
        elif self.n_kv_heads == 1:
            n_kv = 1        # keep MQA archs MQA
        return replace(
            self, name=self.name + "-smoke", n_layers=n_layers,
            d_model=64, n_heads=n_heads, n_kv_heads=n_kv,
            head_dim=16 if self.n_heads else 0,
            d_ff=128, vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            moe=moe, ssm=ssm, layer_pattern=pat,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            dtype="float32", param_dtype="float32", remat=False,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode | long_decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "long_decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(cfg: ModelConfig) -> list:
    """Shape cells that are well-defined for this arch (others are recorded
    as skips — see DESIGN.md §Arch-applicability)."""
    out = [TRAIN_4K, PREFILL_32K]
    if cfg.causal:
        out.append(DECODE_32K)
        subquadratic = (
            cfg.attention_free
            or bool(cfg.layer_pattern)          # hybrid: attn is periodic/shared
            or (cfg.sliding_window > 0)
        )
        if subquadratic:
            out.append(LONG_500K)
    return out


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.kind in ("decode", "long_decode") and not cfg.causal:
        return "encoder-only arch: no autoregressive decode step"
    if shape.kind == "long_decode":
        subq = cfg.attention_free or bool(cfg.layer_pattern) or cfg.sliding_window > 0
        if not subq:
            return "pure full attention: 512k KV cache is quadratic-cost; skipped per spec"
    return None


# ---------------------------------------------------------------------------
# Hardware model (TPU v5e) — used by the roofline analysis and benchmarks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    ici_links: int = 4               # links per chip on a 2D torus (x+/-, y+/-)
    hbm_bytes: float = 16e9          # v5e HBM capacity
    vmem_bytes: float = 128 * 2**20  # ~128MB VMEM
    # achieved-FLOPs factor used ONLY by the timeline simulator
    # (benchmarks/) to match measured step times; the roofline terms always
    # use peak.  A100 value calibrated so the baseline a2a fraction matches
    # the paper's Table 1 (~0.35); see EXPERIMENTS.md §Benchmarks.
    sim_efficiency: float = 0.5


V5E = HardwareConfig()

# The paper's testbed: 4x A100-40GB per node, 100Gbps InfiniBand.  The
# all-to-all/allreduce bottleneck lives on the NIC: 12.5 GB/s per node
# shared by 4 GPUs => ~3.1 GB/s effective per GPU.  Used by the benchmark
# harness to validate the reproduction against the paper's own numbers
# before reporting the v5e-adapted ones (DESIGN.md §2).
A100_IB = HardwareConfig(
    name="a100-100gbIB",
    peak_flops=312e12,
    hbm_bw=1555e9,
    ici_bw=3.125e9,
    ici_links=1,
    hbm_bytes=40e9,
    vmem_bytes=40 * 2**20,
    sim_efficiency=0.04,
)
