"""granite-34b — dense code LM, llama-arch w/ MQA. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,            # MQA (GQA kv=1)
    d_ff=24576,
    vocab_size=49152,
    ffn_type="gelu",         # GPT-BigCode style 4x MLP
    qkv_bias=True,
    notes="IBM Granite Code 34B: MQA, 4x GELU MLP.",
)
