"""qwen1.5-0.5b — dense, MHA w/ QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,           # MHA (GQA kv=16 == heads)
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    notes="Qwen1.5-0.5B: QKV bias, tied embeddings, SwiGLU.",
)
