"""llava-next-34b — VLM; dense LM backbone + vision-stub frontend.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — backbone only; the
anyres vision tower is a STUB: input_specs() provides precomputed patch
embeddings (B, n_patches, d_model) prepended to the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision_stub",
    n_patches=576,              # 24x24 anyres base grid
    notes="LLaVA-NeXT-34B backbone (Yi-34B-like); anyres tiling stubbed.",
)
