"""mixtral-8x22b — MoE 8e top-2, SWA. [arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,         # SWA => long_500k decode runs (bounded KV)
    moe=MoEConfig(
        n_experts=8,
        top_k=2,                 # matches Lina's training setting (k=2)
        d_ff=16384,
        every=1,
        capacity_factor=1.25,
        n_microops=4,
        pipeline_ffn=True,
    ),
    opt_state_dtype="bfloat16",
    notes="Every layer MoE; top-2 routing as in the paper's training setup.",
)
