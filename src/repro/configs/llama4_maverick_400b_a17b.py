"""llama4-maverick-400b-a17b — MoE 128e top-1, interleaved MoE + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
400B total / 17B active: MoE every 2nd layer (24 of 48), 128 routed experts
(top-1) each d_ff=8192, plus an always-on shared expert; dense layers use a
16384 SwiGLU FFN.  This is the paper-representative Lina cell (a2a micro-op
scheduling + popularity placement both fully apply).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,                 # dense (non-MoE) layers
    vocab_size=202048,
    rope_theta=500_000.0,
    moe=MoEConfig(
        n_experts=128,
        top_k=1,
        d_ff=8192,              # routed-expert hidden
        every=2,                # interleave_moe_layer_step=2
        shared_expert=True,
        capacity_factor=1.25,
        n_microops=4,
        pipeline_ffn=True,
    ),
    param_dtype="bfloat16",      # 400B: fp32 master would overflow HBM
    opt_state_dtype="bfloat16",
    notes="Early-fusion multimodality out of scope (text path only).",
)
