"""qwen3-8b — dense, GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    notes="Qwen3-8B: RMSNorm on q/k heads, SwiGLU, no QKV bias.",
)
