"""hubert-xlarge — encoder-only audio transformer. [arXiv:2106.07447; unverified]

Backbone only: the conv waveform frontend is a STUB; input_specs() provides
precomputed frame embeddings (B, n_frames, d_model).  Encoder-only => no
decode shapes.  Training objective: masked-unit prediction over 504 units.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,              # MHA
    d_ff=5120,
    vocab_size=504,             # k-means target units
    ffn_type="gelu",
    causal=False,               # bidirectional encoder
    frontend="audio_stub",
    notes="Same backbone family as wav2vec2; conv frontend stubbed.",
)
