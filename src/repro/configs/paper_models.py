"""The paper's own benchmark models (§7.1), as MoE-converted configs.

All FFN layers are converted to MoE layers (every=1); top-2 gating in
training, top-1 in inference, following [23] and the paper's setup.  The
expert count is a parameter (2/4/8/16 in the paper); helpers below build the
exact variants used by the benchmark harness.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig, MoEConfig


def _moe(n_experts: int, top_k: int = 2) -> MoEConfig:
    return MoEConfig(n_experts=n_experts, top_k=top_k, d_ff=0, every=1,
                     capacity_factor=1.25, n_microops=4, pipeline_ffn=True)


# Transformer-XL (24L encoder in the paper's training set; the 12/24/36L +
# param sizes of Table 1 come from scaling this base).
TRANSFORMER_XL = ModelConfig(
    name="transformer-xl-moe",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=32000,
    ffn_type="gelu",
    moe=_moe(16),
    notes="Paper §7.1 training model (Enwik8 text generation at inference).",
)

GPT2_MOE = ModelConfig(
    name="gpt2-moe",
    family="moe",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=50257,
    ffn_type="gelu",
    moe=_moe(16),
    notes="Paper §7.1: 12-layer decoder.",
)

BERT2GPT2 = ModelConfig(
    name="bert2gpt2-moe",
    family="moe",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=30522,
    ffn_type="gelu",
    moe=_moe(16),
    notes="Paper §7.1: 12-layer encoder-decoder (modelled as a 12L stack).",
)

BERT_LARGE = ModelConfig(
    name="bert-large-moe",
    family="moe",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=30522,
    ffn_type="gelu",
    causal=False,
    moe=_moe(16, top_k=1),
    notes="Paper §7.1 inference model (WMT En-De translation).",
)


def with_experts(cfg: ModelConfig, n_experts: int, top_k: int = None) -> ModelConfig:
    k = top_k if top_k is not None else cfg.moe.top_k
    return replace(cfg, name=f"{cfg.name}-{n_experts}e",
                   moe=replace(cfg.moe, n_experts=n_experts, top_k=k))
