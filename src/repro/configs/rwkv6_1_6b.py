"""rwkv6-1.6b — Finch: attention-free, data-dependent decay. [arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    ssm=SSMConfig(d_state=64, expand=1, head_dim=64, chunk=64),
    notes="RWKV6 time-mix (data-dependent decay w) + channel-mix; "
          "O(1) state per token => long_500k applies.",
)
