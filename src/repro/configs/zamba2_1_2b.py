"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks. [arXiv:2411.15242; hf]

38 Mamba2 layers; every 6th layer additionally runs a SHARED (single weight
set) attention+MLP block ('*' in the pattern).  ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig

# 38 layers: mamba everywhere, shared-attn tap every 6th layer.
_PATTERN = "".join("*" if (i + 1) % 6 == 0 else "M" for i in range(38))

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,              # shared block uses MHA
    d_ff=8192,
    vocab_size=32000,
    layer_pattern=_PATTERN,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, chunk=128),
    tie_embeddings=True,
    notes="Zamba2: Mamba2 backbone + one shared attention block reused "
          "periodically; sub-quadratic => long_500k applies.",
)
