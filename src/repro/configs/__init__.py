"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, HardwareConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, V5E,
    applicable_shapes, skip_reason,
)

from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.qwen3_8b import CONFIG as QWEN3_8B
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN1_5_0_5B
from repro.configs.qwen2_72b import CONFIG as QWEN2_72B
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2_1_2B
from repro.configs.rwkv6_1_6b import CONFIG as RWKV6_1_6B
from repro.configs.hubert_xlarge import CONFIG as HUBERT_XLARGE
from repro.configs.paper_models import (
    TRANSFORMER_XL, GPT2_MOE, BERT2GPT2, BERT_LARGE, with_experts,
)

ASSIGNED = [
    GRANITE_34B, QWEN3_8B, QWEN1_5_0_5B, QWEN2_72B, LLAVA_NEXT_34B,
    LLAMA4_MAVERICK, MIXTRAL_8X22B, ZAMBA2_1_2B, RWKV6_1_6B, HUBERT_XLARGE,
]
PAPER = [TRANSFORMER_XL, GPT2_MOE, BERT2GPT2, BERT_LARGE]

REGISTRY = {c.name: c for c in ASSIGNED + PAPER}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).smoke()
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}") from None


def list_archs() -> list:
    return [c.name for c in ASSIGNED]
