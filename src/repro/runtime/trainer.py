"""Fault-tolerant training loop.

Production behaviors exercised here (and in tests):
  * checkpoint/restart: atomic keep-k checkpoints; on start the Trainer
    resumes from the latest checkpoint and — because the data pipeline is
    step-indexed — reproduces the exact batch sequence (bitwise resume);
  * failure injection: ``fail_at_step`` raises mid-run to simulate a node
    loss; the restart test proves recovery;
  * straggler watchdog: per-step wall time is tracked against a rolling
    median; outliers are logged (on a real cluster this feeds the
    reallocation logic; here it is observable behavior under test);
  * non-finite guard (repro.resilience): a step whose loss/metrics come
    back NaN/inf is SKIPPED — params/opt state keep their pre-step values —
    and ``max_bad_steps`` consecutive bad steps trigger a rollback to the
    newest verified checkpoint; step-indexed data keeps the replay exact;
  * expert packing controller (paper §6.1): after ``pack_warmup`` steps the
    Trainer re-evaluates experts-per-device from measured FFN vs a2a
    micro-op times (the analytic v5e model stands in for CUDA events).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.obs import ObsContext
from repro.configs.base import ModelConfig
from repro.core.packing import choose_packing
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import ep_size
from repro.launch.steps import make_train_step
from repro.models import lm as lm_mod
from repro.optim import reduce as reduce_mod
from repro.optim.adamw import AdamWConfig, init_opt_state


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    lina: bool = True
    microbatches: int = 1
    # Lina §4 gradient-reduction schedule (optim/reduce.py).  "baseline" is
    # an explicit single fused psum; the priority* schedules order/partition
    # it after the backward a2a.  Default None keeps the implicit XLA
    # reduction: the explicit reduce runs ON TOP of the partitioner's own
    # DP reduction (one extra param-sized collective per step), so it is
    # opt-in — for the measured ablation, schedule experiments, and
    # compression — not the steady-state default.
    schedule: Optional[str] = None
    partition_bytes: float = reduce_mod.DEFAULT_PARTITION_BYTES
    grad_compression: Optional[str] = None   # None | "bf16" | "int8_ef"
    # token dispatch/combine backend (core.dispatch.BACKENDS): "scatter"
    # (jnp production), "einsum" (oracle), or "pallas" (fused kernels —
    # pairs with MoEConfig.compute_backend="pallas")
    dispatch_backend: str = "scatter"
    # Overlap knobs (None = keep the model config's values).  Applied onto
    # ``model_cfg.moe`` at construction so CLI flags (launch/train.py) reach
    # the shard-map body; the effective values are logged per step like
    # ``schedule`` is.
    n_microops: Optional[int] = None
    pipeline_ffn: Optional[bool] = None
    shortcut: Optional[bool] = None
    fail_at_step: Optional[int] = None       # failure injection (tests)
    straggler_factor: float = 3.0
    pack_warmup: int = 10                    # paper: packing decided at step 10
    seed: int = 0
    # non-finite guard: skip steps with NaN/inf metrics; roll back to the
    # newest checkpoint after this many CONSECUTIVE bad steps (0 = guard off)
    max_bad_steps: int = 3
    nan_at_steps: tuple = ()                 # fault injection: force these
    #                                          steps' metrics non-finite


class Trainer:
    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig,
                 opt_cfg: AdamWConfig, cfg: TrainerConfig, mesh=None,
                 obs: Optional[ObsContext] = None):
        self.obs = obs or ObsContext.disabled()
        moe_over = {k: v for k, v in (("n_microops", cfg.n_microops),
                                      ("pipeline_ffn", cfg.pipeline_ffn),
                                      ("shortcut", cfg.shortcut))
                    if v is not None}
        if moe_over:
            model_cfg = replace(model_cfg,
                                moe=replace(model_cfg.moe, **moe_over))
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.mesh = mesh
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.dataset = SyntheticLM(data_cfg)
        self.stateful_reduce = cfg.grad_compression == "int8_ef"
        self.step_fn = jax.jit(make_train_step(
            model_cfg, mesh, opt_cfg, lina=cfg.lina,
            dispatch_backend=cfg.dispatch_backend,
            microbatches=cfg.microbatches, fsdp=False,
            schedule=cfg.schedule, partition_bytes=cfg.partition_bytes,
            grad_compression=cfg.grad_compression))
        self.metrics_log: list = []
        self.straggler_events: list = []
        self.packing_decision = None
        self.skipped_steps: list = []        # non-finite guard: steps skipped
        self.rollbacks = 0                   # checkpoint rollbacks performed

    def init_state(self):
        params = lm_mod.init_params(self.model_cfg,
                                    jax.random.PRNGKey(self.cfg.seed))
        state = {"params": params,
                 "opt_state": init_opt_state(params, self.opt_cfg)}
        if self.stateful_reduce:
            # int8-EF residual rides in the checkpoint so resume is bitwise
            state["reduce_state"] = reduce_mod.init_reduce_state(
                params, reduce_mod.ReduceConfig(
                    schedule=self.cfg.schedule,
                    partition_bytes=self.cfg.partition_bytes,
                    compression=self.cfg.grad_compression))
        return state

    def run(self, on_step: Optional[Callable] = None) -> dict:
        state = self.init_state()
        start, restored = self.ckpt.restore_latest(state)
        if restored is not None:
            state = restored
            start_step = start
        else:
            start_step = 0

        times: list = []
        consec_bad = 0
        tr = self.obs.tracer
        met = self.obs.metrics
        sched_name = self.cfg.schedule or "implicit"
        for step in range(start_step, self.cfg.steps):
            if self.cfg.fail_at_step is not None and step == self.cfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            with tr.span("train.step", step=step,
                         schedule=sched_name) as ssp:
                with tr.span("data.batch"):
                    batch = {k: jax.numpy.asarray(v)
                             for k, v in self.dataset.batch(step).items()}
                # fwd+bwd+update runs as ONE jitted call — the host-side
                # span carries the schedule attribution; the true device
                # split lives in a jax.profiler capture (obs.StepProfiler)
                with tr.timed("fwd_bwd", schedule=sched_name) as sw:
                    if self.stateful_reduce:
                        params, opt_state, m, rstate = self.step_fn(
                            state["params"], state["opt_state"], batch,
                            state["reduce_state"])
                    else:
                        params, opt_state, m = self.step_fn(
                            state["params"], state["opt_state"], batch)
                    m = {k: float(v) for k, v in m.items()}
                if step in (self.cfg.nan_at_steps or ()):
                    m = dict(m, loss=float("nan"))   # injected divergence
                dt = sw.dt
                met.counter("trainer_steps_total").inc()
                met.histogram("trainer_step_s").observe(dt)
                # --- non-finite guard: a diverged step must not commit -----
                if self.cfg.max_bad_steps and \
                        not all(np.isfinite(v) for v in m.values()):
                    self.skipped_steps.append(step)
                    self.metrics_log.append({"step": step, **m, "dt": dt,
                                             "skipped": True})
                    met.counter("trainer_skipped_steps_total").inc()
                    ssp.set(skipped=True)
                    consec_bad += 1
                    if consec_bad >= self.cfg.max_bad_steps:
                        _, rb_state = self.ckpt.restore_latest(state)
                        if rb_state is not None:
                            state = rb_state
                            self.rollbacks += 1
                            met.counter("trainer_rollbacks_total").inc()
                            ssp.set(rollback=True)
                        consec_bad = 0
                    continue     # params/opt_state keep pre-step values
                consec_bad = 0
                state = {"params": params, "opt_state": opt_state}
                if self.stateful_reduce:
                    state["reduce_state"] = rstate
                times.append(dt)
                med = float(np.median(times[-20:]))
                if len(times) > 5 and dt > self.cfg.straggler_factor * med:
                    self.straggler_events.append({"step": step, "dt": dt,
                                                  "median": med})
                    met.counter("trainer_straggler_events_total").inc()
                # per-schedule step time: the measured ablation keys on
                # this; overlap knobs logged alongside so ablations over
                # n_microops/pipeline/shortcut are attributable per step
                moe = self.model_cfg.moe
                self.metrics_log.append({"step": step, **m, "dt": dt,
                                         "schedule": sched_name,
                                         "n_microops": moe.n_microops,
                                         "pipeline_ffn": moe.pipeline_ffn,
                                         "shortcut": moe.shortcut})
                if step == self.cfg.pack_warmup and self.model_cfg.moe.enabled:
                    self._decide_packing()
                if on_step:
                    on_step(step, m)
                if (step + 1) % self.cfg.ckpt_every == 0 or \
                        step + 1 == self.cfg.steps:
                    with tr.span("checkpoint", step=step + 1):
                        self.ckpt.save(step + 1, state)
        return state

    def _decide_packing(self):
        mc = self.model_cfg
        # EP group size from the actual mesh; the paper's one-expert-per-
        # device assumption only stands in when there is no mesh to ask
        ep = ep_size(self.mesh) if self.mesh is not None else mc.moe.n_experts
        tokens = (self.data_cfg.global_batch * self.data_cfg.seq_len
                  // max(ep, 1) // max(mc.moe.n_microops, 1))
        self.packing_decision = choose_packing(
            max(tokens, 1), mc.d_model, mc.moe.d_ff or mc.d_ff,
            mc.moe.n_experts, ep,
            ffn_mult=3 if mc.ffn_type == "swiglu" else 2)
