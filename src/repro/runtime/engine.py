"""Continuous-batching front end for the two-phase MoE server (§5/§6.2).

Requests enter a FIFO queue with arrival timestamps and a
``max_new_tokens`` generation budget, then move through a lifecycle:

    queued -> prefill -> decoding -> done

Each engine step forms a micro-batch under a shared token budget that MIXES
the two phases: in-flight decodes cost one token each and are admitted
first (they are the latency-bound regime Lina's §5 targets), and the
remaining budget admits newly queued prefills FCFS.  Prefills run through
``MoEServer.prefill_batch`` — the plan-honoring distributed dispatch with a
cross-batch PlanCache — which returns last-token logits plus a KV cache;
the engine then parks each generating request in a *decode slot* that
persists its per-request KV cache and rolling path-ID state across steps,
and subsequent steps drive ``MoEServer.decode_batch`` one token at a time.
A request with ``max_new_tokens == 0`` completes at prefill with its
last-prompt logits (the PR-1 scoring behavior).

Gating capacity is sized from *valid* tokens (see
``MoEServer._valid_capacity``), so bucket padding never changes a real
request's dispatch.  Each request's rolling path-ID state is kept (bounded)
after completion: submitting a follow-up with ``prev_rid`` seeds the next
request's popularity estimation from where the last one left off.  States
of still-active (mid-decode) requests are pinned and never evicted.

Latency accounting supports both wall-clock serving (``submit`` stamps
arrivals from the engine clock) and open-loop trace replay (``simulate``):
virtual arrival times drive queueing delay while the measured wall time of
each step drives service time.  Per-request TTFT (time of the first
generated token) and completion times support time-per-output-token
reporting.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models.attention import KVCache
from repro.models.lm import LMCache
from repro.obs import ObsContext
from repro.obs.tracer import Span
from repro.runtime.server import LayerStats, MoEServer


@dataclass
class EngineConfig:
    max_batch_tokens: int = 1024   # token budget per micro-batch
    max_batch_requests: int = 16   # row cap per micro-batch (each phase)
    pad_to_pow2: bool = True       # bucket batch rows to powers of two
    state_cache: int = 4096        # completed path states kept for follow-ups
    stats_window: int = 4096       # LayerStats retained for metrics
    # admission control (repro.resilience): overload degrades to explicit
    # rejections / deadline sheds instead of unbounded queueing latency
    max_queue: int = 0             # queue-depth cap; submit returns -1 when
    #                                full (0 = unbounded, legacy behavior)
    deadline_s: float = 0.0        # shed queued (never mid-decode) requests
    #                                older than this at step start (0 = off)


@dataclass(frozen=True)
class ShedRecord:
    """One explicitly refused request — the accounting that distinguishes
    load shedding from silent loss (chaos suite invariant: every offered
    request is completed or lands here)."""
    rid: int                       # -1: rejected before an id was assigned
    arrival: float
    time: float                    # when the engine gave up on it
    reason: str                    # "deadline" | "rejected"


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                       # [S] token ids
    arrival: float                           # queue-entry timestamp
    path_state: Optional[np.ndarray] = None  # [S] rolling path ids
    max_new_tokens: int = 0                  # 0 => score-only (no decode)


@dataclass
class DecodeSlot:
    """Per-request state persisted across decode steps: the KV cache slice
    owned by this request plus its rolling path-ID state.  While the decode
    batch's membership is stable the engine keeps the whole *batched* cache
    resident and slots only hold a (batch, row) reference; the per-request
    slice is materialized lazily when the batch has to be rebuilt."""
    rid: int
    arrival: float
    prompt_len: int
    max_new_tokens: int
    cap: int                                 # cache capacity (time slots)
    kv_k: object                             # [G, every, S_cap, KV, hd]|None
    kv_v: object
    pos: int                                 # next cache slot / abs position
    path_scalar: int                         # most recent token's path hash
    path_history: List[int]                  # per-token rolling states
    gen_tokens: List[int]                    # generated token ids
    ttft: float                              # completion time of first token
    batch_ref: Optional[object] = None       # LMCache holding this row
    batch_row: int = 0

    def materialize(self):
        """Own KV slice, pulling it out of the batched cache if needed."""
        if self.batch_ref is not None:
            kv = self.batch_ref.kv
            self.kv_k = kv.k[:, :, self.batch_row, :self.cap]
            self.kv_v = kv.v[:, :, self.batch_row, :self.cap]
            self.batch_ref = None
        return self.kv_k, self.kv_v


@dataclass
class RequestResult:
    rid: int
    logits: np.ndarray                       # [V] logits of the last step
    arrival: float
    completion: float
    n_tokens: int                            # prompt length
    tokens: Optional[np.ndarray] = None      # generated ids (None: score-only)
    ttft: Optional[float] = None             # first-token completion time

    @property
    def latency(self) -> float:
        return self.completion - self.arrival

    @property
    def n_generated(self) -> int:
        return 0 if self.tokens is None else int(len(self.tokens))

    @property
    def ttft_latency(self) -> Optional[float]:
        return None if self.ttft is None else self.ttft - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token over the decode phase (excludes prefill)."""
        if self.ttft is None or self.n_generated < 2:
            return None
        return (self.completion - self.ttft) / (self.n_generated - 1)


class ServingEngine:
    """Queue -> prefill/decode micro-batches -> plan-cached dispatch."""

    def __init__(self, server: MoEServer, ecfg: Optional[EngineConfig] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 scheduler=None,
                 service_model: Optional[Callable] = None,
                 fault_injector=None,
                 obs: Optional[ObsContext] = None):
        """``scheduler`` is an ``repro.sched.AdaptiveScheduler``: after each
        micro-batch the engine feeds it the step's LayerStats and served
        token count, and controller-published plans take effect from the
        next micro-batch (decode state survives the swap).

        ``service_model`` maps (step LayerStats list, n_tokens) -> modeled
        seconds of *distributed* service time added on top of the measured
        wall time in virtual-clock replay (``step(now=...)``): the paper's
        methodology, where per-device load imbalance — invisible to
        single-host wall time — slows the step via its straggler link (see
        ``benchmarks.inference_model``).  Ignored in wall-clock mode.

        ``fault_injector`` is a ``repro.resilience.FaultInjector``: called
        at each step start (fault firing) and between the step's stats and
        the scheduler (telemetry corruption).

        ``obs`` is a ``repro.obs.ObsContext``.  The serving stack shares
        ONE context: passing it here also installs it on the server;
        omitting it inherits the server's (so enabling tracing at either
        end wires the whole stack)."""
        self.server = server
        if obs is not None:
            self.obs = obs
            server.obs = obs
            # a scheduler built before this engine captured the server's
            # previous registry — re-point its bus at the shared one
            bus = getattr(scheduler, "bus", None)
            if bus is not None and bus.metrics is not None:
                bus.metrics = obs.metrics
        else:
            self.obs = getattr(server, "obs", None) or ObsContext.disabled()
        # open request-lifecycle spans by rid (tracer enabled only)
        self._req_spans: Dict[int, Span] = {}
        self.ecfg = ecfg or EngineConfig()
        self.clock = clock
        self.scheduler = scheduler
        self.service_model = service_model
        self.fault_injector = fault_injector
        if fault_injector is not None:
            fault_injector.attach(self)
        self.step_idx = 0
        self.n_submitted = 0
        self.n_rejected = 0
        self.shed_records: List[ShedRecord] = []
        self._step_stats: List[LayerStats] = []
        self._queue: Deque[Request] = deque()
        self._active: "OrderedDict[int, DecodeSlot]" = OrderedDict()
        self._path_states: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._next_rid = 0
        self.layer_stats: Deque[LayerStats] = deque(
            maxlen=self.ecfg.stats_window)
        self._finetunes = 0
        self._layers_served = 0
        self.last_step_end: Optional[float] = None   # stamp of the last step
        # (rids, LMCache) of the last decode batch: reused verbatim while
        # the batch membership is unchanged, so steady-state decoding does
        # not re-pad/re-stack every request's cache each token
        self._dec_batch: Optional[tuple] = None

    # --- queueing -----------------------------------------------------------
    def submit(self, tokens, arrival: Optional[float] = None,
               prev_rid: Optional[int] = None,
               max_new_tokens: int = 0) -> int:
        """Enqueue one request; returns its id.  ``prev_rid`` names an
        earlier request of the same stream: the new request seeds its
        rolling path-ID state from that request's final state.
        ``max_new_tokens > 0`` turns the request into a generation request
        that decodes incrementally through the KV cache after prefill.

        With ``EngineConfig.max_queue`` set, a full queue REJECTS the
        request: returns -1 (no id is consumed) and counts it in
        ``n_rejected`` — explicit backpressure the caller can retry on
        (see ``simulate``'s retry-with-backoff client)."""
        if self.ecfg.max_queue and len(self._queue) >= self.ecfg.max_queue:
            self.n_rejected += 1
            self.obs.metrics.counter("engine_requests_rejected_total").inc()
            return -1
        tokens = np.asarray(tokens).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        self.n_submitted += 1
        self.obs.metrics.counter("engine_requests_offered_total").inc()
        state = None if prev_rid is None else self.request_path_state(prev_rid)
        req = Request(rid, tokens,
                      self.clock() if arrival is None else arrival,
                      path_state=state, max_new_tokens=int(max_new_tokens))
        self._queue.append(req)
        tr = self.obs.tracer
        if tr.enabled:
            root = tr.begin("request", start=req.arrival, rid=rid,
                            n_tokens=int(tokens.shape[0]),
                            max_new_tokens=int(max_new_tokens))
            root.begin_child("queued", req.arrival)
            self._req_spans[rid] = root
        return rid

    def record_shed(self, rid: int, arrival: float, time: float,
                    reason: str) -> None:
        self.shed_records.append(ShedRecord(rid, arrival, time, reason))
        met = self.obs.metrics
        met.counter("engine_requests_shed_total", reason=reason).inc()
        if rid < 0:
            # a give-up after retries never got an id, so it was never
            # counted at submit — count it here to keep the ledger closed:
            # offered == completed + shed
            met.counter("engine_requests_offered_total").inc()
        root = self._req_spans.pop(rid, None)
        if root is not None:
            for c in root.children:          # close the open queued phase
                if c.name == "queued" and c.end != c.end:
                    c.end_at(time)
            root.end_at(time, outcome=f"shed:{reason}")

    def _shed_expired(self, now: float) -> None:
        """Deadline-based load shedding: drop QUEUED requests whose wait
        already exceeds ``deadline_s`` (mid-decode requests are never shed
        — their slot state is paid for).  Every drop is recorded, never
        silent."""
        dl = self.ecfg.deadline_s
        if not dl:
            return
        kept: Deque[Request] = deque()
        for req in self._queue:
            if now - req.arrival > dl:
                self.record_shed(req.rid, req.arrival, now, "deadline")
            else:
                kept.append(req)
        self._queue = kept

    def pending(self) -> int:
        return len(self._queue)

    def active(self) -> int:
        return len(self._active)

    def has_work(self) -> bool:
        return bool(self._queue or self._active)

    def request_path_state(self, rid: int) -> Optional[np.ndarray]:
        for req in self._queue:             # still waiting: pre-step state
            if req.rid == rid:
                return req.path_state
        slot = self._active.get(rid)        # mid-decode: state so far
        if slot is not None:
            return np.asarray(slot.path_history, np.int64)
        return self._path_states.get(rid)

    # --- micro-batch formation ---------------------------------------------
    def _form_microbatch(self, budget: Optional[int] = None,
                         gen_slots: Optional[int] = None) -> List[Request]:
        """FCFS under the token budget; always admits the queue head so an
        over-budget single request still makes progress (unless decodes
        already consumed the whole budget: ``budget <= 0``).  Generating
        requests are additionally admitted only while free decode slots
        remain (``gen_slots``, default ``max_batch_requests - active``) —
        the continuous-batching backpressure that bounds the in-flight KV
        working set; FCFS order is preserved, so a blocked generating head
        also holds back later arrivals."""
        ecfg = self.ecfg
        batch: List[Request] = []
        budget = ecfg.max_batch_tokens if budget is None else budget
        if gen_slots is None:
            gen_slots = max(0, ecfg.max_batch_requests - len(self._active))
        admit_head = budget > 0
        while self._queue and len(batch) < ecfg.max_batch_requests:
            nxt = self._queue[0]
            cost = nxt.tokens.shape[0]
            if cost > budget and not (admit_head and not batch):
                break
            if nxt.max_new_tokens > 1:
                if gen_slots <= 0:
                    break               # no decode slot free: FCFS waits
                gen_slots -= 1
            batch.append(self._queue.popleft())
            budget -= cost
        return batch

    @staticmethod
    def _bucket_rows(n: int) -> int:
        return 1 << (n - 1).bit_length()

    def _remember_state(self, rid: int, state: np.ndarray) -> None:
        self._path_states[rid] = np.asarray(state)
        self._path_states.move_to_end(rid)
        excess = len(self._path_states) - self.ecfg.state_cache
        if excess <= 0:
            return
        for old in list(self._path_states):
            if excess <= 0:
                break
            if old in self._active:          # never drop mid-decode state
                continue
            del self._path_states[old]
            excess -= 1

    # --- serving ------------------------------------------------------------
    def step(self, now: Optional[float] = None, time_scale: float = 1.0
             ) -> List[RequestResult]:
        """Serve one micro-batch: all in-flight decodes (one token each,
        admitted first) plus newly queued prefills under the remaining
        token budget.  Returns requests completed this step (possibly
        empty while generation is in flight).  With ``now`` given,
        completions are stamped ``now + wall_service * time_scale``
        (virtual-clock replay); otherwise from the engine clock."""
        ecfg = self.ecfg
        self.step_idx += 1
        t_now = self.clock() if now is None else now
        if self.fault_injector is not None:
            # faults fire before batch formation: an overload burst's
            # requests are admissible this step, a device failure degrades
            # this step's routing
            self.fault_injector.on_step(self, t_now)
        self._shed_expired(t_now)
        decodes = list(self._active.values())[:ecfg.max_batch_requests]
        decodes = decodes[:ecfg.max_batch_tokens]
        prefills = self._form_microbatch(
            budget=ecfg.max_batch_tokens - len(decodes))
        if not decodes and not prefills:
            self.last_step_end = None
            return []

        self._step_stats = []
        tr = self.obs.tracer
        # Three measured service phases (the TTFT decomposition): time spent
        # behind the decode batch is queueing, the prefill forward is
        # prefill, and slot insertion / first-token argmax is insert.  The
        # stopwatches always run (their sum is the service-time stamp);
        # span recording rides on the explicit-timestamp layout below so
        # spans land on the SAME clock as completions (virtual in replay).
        with tr.timed("decode", record=False) as sw_dec:
            dec_res = self._run_decodes(decodes) if decodes else None
        with tr.timed("prefill", record=False) as sw_pre:
            pre_parts = self._run_prefills(prefills) if prefills else []
        n_tokens = len(decodes) + sum(r.tokens.shape[0] for r in prefills)
        extra = 0.0
        if now is not None and self.service_model is not None:
            extra = float(self.service_model(self._step_stats, n_tokens))

        # Finish with a NaN placeholder stamp while the insert phase is
        # still being measured (its wall time is part of the service that
        # determines the stamp), then patch every stamp minted this step.
        pending = float("nan")
        out: List[RequestResult] = []
        with tr.timed("insert", record=False) as sw_ins:
            if dec_res is not None:
                out.extend(self._finish_decodes(decodes, dec_res, pending))
            for group, res in pre_parts:
                out.extend(self._finish_prefills(group, res, pending))
        service = sw_dec.dt + sw_pre.dt + sw_ins.dt
        if now is None:
            completion = self.clock()
        else:
            completion = now + service * time_scale + extra
        self.last_step_end = completion
        for r in out:
            r.completion = completion
            if r.ttft is not None and r.ttft != r.ttft:
                r.ttft = completion          # first token minted this step
        for slot in self._active.values():
            if slot.ttft != slot.ttft:
                slot.ttft = completion
        scale = 1.0 if now is None else time_scale
        self._observe_step(t_now, completion, scale, extra,
                           (sw_dec.dt, sw_pre.dt), decodes, pre_parts, out)
        if self.scheduler is not None:
            # between micro-batches: feed telemetry, maybe publish plans —
            # they apply from the NEXT step, never mid-batch.  The injector
            # corrupts the observed stats here (telemetry faults poison the
            # control loop's view, not the actual serving math).
            stats = self._step_stats
            if self.fault_injector is not None:
                stats = self.fault_injector.filter_stats(stats)
            self.scheduler.after_step(stats, n_tokens)
        return out

    # --- observability ------------------------------------------------------
    def _observe_step(self, t_now, completion, scale, extra, walls,
                      decodes, pre_parts, out) -> None:
        """Publish the step into the obs context: registry metrics always,
        span trees only when the tracer is enabled.  Phase boundaries are
        laid out on the completion clock (virtual during replay):
        ``[t_now, t_dec_end, t_pre_end, completion]`` — so for a request
        prefilled this step, queue + prefill + insert == TTFT exactly."""
        wall_dec, wall_pre = walls
        t_dec_end = t_now + wall_dec * scale
        t_pre_end = t_dec_end + wall_pre * scale + extra
        met = self.obs.metrics
        met.counter("engine_steps_total").inc()
        met.histogram("engine_step_service_s").observe(completion - t_now)
        if decodes:
            # TPOT by decode occupancy: the decode phase advances every
            # in-flight request one token, so its duration IS this step's
            # time-per-output-token at that occupancy
            occ = self._bucket_rows(len(decodes))
            met.histogram("engine_decode_step_s",
                          occupancy=str(occ)).observe(t_dec_end - t_now)
        prefilled = [r for group, _res in pre_parts for r in group]
        for r in prefilled:
            if r.max_new_tokens >= 1:
                met.histogram("engine_ttft_s").observe(completion - r.arrival)
                met.histogram("engine_ttft_queue_s").observe(
                    t_dec_end - r.arrival)
                met.histogram("engine_ttft_prefill_s").observe(
                    t_pre_end - t_dec_end)
                met.histogram("engine_ttft_insert_s").observe(
                    completion - t_pre_end)
        for r in out:
            if r.tpot is not None:
                met.histogram("engine_tpot_s").observe(r.tpot)
        if out:
            met.counter("engine_requests_completed_total").inc(len(out))
        if self.obs.tracer.enabled:
            self._trace_step(t_now, t_dec_end, t_pre_end, completion,
                             decodes, prefilled, out)

    def _trace_step(self, t_now, t_dec_end, t_pre_end, completion,
                    decodes, prefilled, out) -> None:
        """Span trees for one step: an ``engine.step`` root with the three
        phase children, plus per-request lifecycle updates (decode-step
        ticks, the queued→prefill→insert TTFT decomposition, completion)."""
        tr = self.obs.tracer
        sp = tr.add("engine.step", t_now, completion, step=self.step_idx,
                    decodes=len(decodes), prefills=len(prefilled))
        sp.child("decode", t_now, t_dec_end, n=len(decodes))
        sp.child("prefill", t_dec_end, t_pre_end, n=len(prefilled))
        sp.child("insert", t_pre_end, completion)
        for slot in decodes:
            root = self._req_spans.get(slot.rid)
            if root is not None:
                root.child("decode_step", t_now, t_dec_end,
                           step=self.step_idx)
        for r in prefilled:
            root = self._req_spans.get(r.rid)
            if root is None:
                continue
            for c in root.children:
                if c.name == "queued" and c.end != c.end:
                    c.end_at(t_dec_end)
            root.child("prefill", t_dec_end, t_pre_end)
            root.child("insert", t_pre_end, completion)
            root.set(queue_s=t_dec_end - root.start,
                     prefill_s=t_pre_end - t_dec_end,
                     insert_s=completion - t_pre_end)
            if r.max_new_tokens >= 1:
                root.set(ttft_s=completion - root.start)
        for r in out:
            root = self._req_spans.pop(r.rid, None)
            if root is not None:
                root.end_at(completion, outcome="done")

    # --- decode phase -------------------------------------------------------
    def _run_decodes(self, slots: List[DecodeSlot]):
        rids = tuple(s.rid for s in slots)
        if self._dec_batch is not None and self._dec_batch[0] == rids:
            cache = self._dec_batch[1]       # pos already advanced inside
            b = cache.kv.k.shape[2]
        else:
            b_real = len(slots)
            b = self._bucket_rows(b_real) if self.ecfg.pad_to_pow2 else b_real
            s_max = max(s.cap for s in slots)

            def pad_kv(a, cap):
                if cap < s_max:
                    a = jnp.pad(a, ((0, 0), (0, 0), (0, s_max - cap),
                                    (0, 0), (0, 0)))
                return a

            ks, vs = [], []
            for s in slots:
                k, v = s.materialize()
                ks.append(pad_kv(k, s.cap))
                vs.append(pad_kv(v, s.cap))
            for _ in range(b - b_real):
                ks.append(jnp.zeros_like(ks[0]))
                vs.append(jnp.zeros_like(vs[0]))
            kv = KVCache(jnp.stack(ks, axis=2), jnp.stack(vs, axis=2))
            pos = np.zeros((b,), np.int32)
            for i, s in enumerate(slots):
                pos[i] = s.pos
            cache = LMCache(kv, None, None, jnp.asarray(pos))
        tokens = np.zeros((b,), np.int64)
        path = np.zeros((b,), np.int64)
        valid = np.zeros((b,), bool)
        for i, s in enumerate(slots):
            tokens[i] = s.gen_tokens[-1]
            path[i] = s.path_scalar
            valid[i] = True
        res = self.server.decode_batch(tokens, cache, path, valid=valid)
        self._record_stats(res.stats)
        self._dec_batch = (rids, res.cache)
        return res

    def _finish_decodes(self, slots, res, completion) -> List[RequestResult]:
        out = []
        done = False
        for i, slot in enumerate(slots):
            nxt = int(np.argmax(res.logits[i]))
            slot.gen_tokens.append(nxt)
            slot.path_scalar = int(res.path_state[i])
            slot.path_history.append(slot.path_scalar)
            slot.pos += 1
            slot.kv_k = slot.kv_v = None     # row lives in the batched cache
            slot.batch_ref = res.cache
            slot.batch_row = i
            if len(slot.gen_tokens) >= slot.max_new_tokens:
                out.append(self._complete_slot(slot, res.logits[i],
                                               completion))
                done = True
        if done:                 # membership changes: next step re-stacks
            self._dec_batch = None
        return out

    def _complete_slot(self, slot: DecodeSlot, logits,
                       completion: float) -> RequestResult:
        del self._active[slot.rid]
        self._remember_state(slot.rid,
                             np.asarray(slot.path_history, np.int64))
        return RequestResult(slot.rid, np.asarray(logits), slot.arrival,
                             completion, slot.prompt_len,
                             tokens=np.asarray(slot.gen_tokens, np.int64),
                             ttft=slot.ttft)

    # --- prefill phase ------------------------------------------------------
    def _assemble(self, batch: List[Request]):
        b_real = len(batch)
        b = self._bucket_rows(b_real) if self.ecfg.pad_to_pow2 else b_real
        s = max(r.tokens.shape[0] for r in batch)
        tokens = np.zeros((b, s), np.int64)
        lengths = np.zeros((b,), np.int64)
        path_init = np.zeros((b, s), np.int64)
        for i, r in enumerate(batch):
            n = r.tokens.shape[0]
            tokens[i, :n] = r.tokens
            lengths[i] = n
            if r.path_state is not None:
                m = min(n, r.path_state.shape[0])
                path_init[i, :m] = r.path_state[:m]
        return tokens, lengths, path_init

    def _run_prefills(self, batch: List[Request]):
        """Score-only rows (max_new_tokens <= 1: no decode cache needed)
        and generating rows run as separate forwards, so a long score-only
        prompt never inflates — or, under a sliding window, invalidates —
        the generating rows' cache allocation.  Returns (group, result)
        pairs."""
        gen = [r for r in batch if r.max_new_tokens > 1]
        score = [r for r in batch if r.max_new_tokens <= 1]
        parts = []
        if score:
            tokens, lengths, path_init = self._assemble(score)
            res = self.server.serve_batch(tokens, lengths=lengths,
                                          path_init=path_init)
            self._record_stats(res.stats)
            parts.append((score, res))
        if gen:
            tokens, lengths, path_init = self._assemble(gen)
            cache_len = max(r.tokens.shape[0] + r.max_new_tokens for r in gen)
            res = self.server.prefill_batch(tokens, lengths=lengths,
                                            path_init=path_init,
                                            cache_len=cache_len)
            self._record_stats(res.stats)
            parts.append((gen, res))
        return parts

    def _finish_prefills(self, batch, res,
                         completion) -> List[RequestResult]:
        out = []
        for i, r in enumerate(batch):
            n = r.tokens.shape[0]
            path_row = np.asarray(res.path_ids[i, :n])
            if r.max_new_tokens <= 0:
                self._remember_state(r.rid, path_row.copy())
                out.append(RequestResult(r.rid, res.logits[i], r.arrival,
                                         completion, n))
                continue
            first = int(np.argmax(res.logits[i]))
            if r.max_new_tokens == 1:
                self._remember_state(r.rid, path_row.copy())
                out.append(RequestResult(
                    r.rid, res.logits[i], r.arrival, completion, n,
                    tokens=np.asarray([first], np.int64), ttft=completion))
                continue
            cap = n + r.max_new_tokens
            slot = DecodeSlot(
                rid=r.rid, arrival=r.arrival, prompt_len=n,
                max_new_tokens=r.max_new_tokens, cap=cap,
                kv_k=None, kv_v=None,
                pos=n, path_scalar=int(path_row[-1]),
                path_history=[int(p) for p in path_row],
                gen_tokens=[first], ttft=completion,
                batch_ref=res.cache, batch_row=i)
            self._active[r.rid] = slot
            # pin the prompt's path state so follow-ups submitted while the
            # stream is still decoding can branch from it
            self._remember_state(r.rid, path_row.copy())
        return out

    def _record_stats(self, stats) -> None:
        self.layer_stats.extend(stats)
        self._step_stats.extend(stats)
        self._finetunes += sum(s.finetuned for s in stats)
        self._layers_served += len(stats)

    # --- warm-up ------------------------------------------------------------
    def warmup(self, seqs=(), max_new_tokens: int = 8,
               min_replicas_grid=(1, 2)) -> int:
        """Pre-trace the compile grid before traffic arrives (ROADMAP
        warm-up follow-up): full prefill+decode at each prompt length in
        ``seqs`` and the plan-honoring dispatch over every (decode
        row-bucket up to ``max_batch_requests``) x ``min_replicas_grid``
        combination — so neither the first request nor a controller plan
        swap to an already-seen replica count compiles inside a timed
        step.  Returns the number of traced calls."""
        rows = range(1, self.ecfg.max_batch_requests + 1)
        return self.server.warmup(seqs=seqs, rows=rows,
                                  min_replicas_grid=min_replicas_grid,
                                  max_new_tokens=max_new_tokens)

    def run(self) -> List[RequestResult]:
        """Drain queue AND in-flight generation in wall-clock mode."""
        results: List[RequestResult] = []
        while self.has_work():
            results.extend(self.step())
        return results

    # --- metrics ------------------------------------------------------------
    @property
    def plan_reuse_rate(self) -> float:
        cache = self.server.plan_cache
        return cache.stats.reuse_rate if cache is not None else 0.0

    @property
    def finetune_rate(self) -> float:
        return self._finetunes / self._layers_served \
            if self._layers_served else 0.0


def summarize_results(results: List[RequestResult],
                      engine: Optional[ServingEngine] = None) -> dict:
    """Latency / TTFT / time-per-output-token percentiles (seconds) and
    decode throughput over a completed result set — the one summarization
    shared by the serve driver, the example, and the traffic benchmark.
    Pass ``engine`` to also surface its admission-control ledger (shed /
    rejected counts)."""
    lat = np.array([r.latency for r in results])
    ttft = np.array([r.ttft_latency for r in results
                     if r.ttft_latency is not None])
    tpot = np.array([r.tpot for r in results if r.tpot is not None])
    n_gen = sum(r.n_generated for r in results)
    span = (max(r.completion for r in results) -
            min(r.arrival for r in results)) if results else 0.0
    pct = lambda a, q: float(np.percentile(a, q)) if a.size else float("nan")
    out = {
        "n": len(results),
        "latency_p50": pct(lat, 50), "latency_p95": pct(lat, 95),
        "ttft_p50": pct(ttft, 50), "ttft_p95": pct(ttft, 95),
        "tpot_p50": pct(tpot, 50), "tpot_p95": pct(tpot, 95),
        "gen_tokens": n_gen,
        "gen_tok_s": n_gen / span if span > 0 else 0.0,
    }
    if engine is not None:
        shed = engine.shed_records
        out["shed_deadline"] = sum(s.reason == "deadline" for s in shed)
        out["shed_rejected"] = sum(s.reason == "rejected" for s in shed)
        out["rejected_submits"] = engine.n_rejected
        out["submitted"] = engine.n_submitted
    return out


def simulate(engine: ServingEngine, requests, time_scale: float = 1.0,
             max_new_tokens: int = 0, retry_backoff_s: float = 0.0,
             max_retries: int = 3,
             on_step: Optional[Callable] = None) -> List[RequestResult]:
    """Open-loop trace replay: ``requests`` is an iterable of
    (tokens, arrival_time) virtual-time pairs.  Queueing delay comes from
    the virtual clock; service time is the measured wall time of each step
    scaled by ``time_scale``.  With ``max_new_tokens > 0`` every request
    generates that many tokens through the incremental-decode path, and a
    request's latency spans prefill + all its decode steps.  Returns
    per-request results whose ``latency`` mixes both — the standard
    open-loop p50/p95 methodology.

    With ``retry_backoff_s`` set the client half of admission control
    engages: a rejected submit (queue full, -1) is re-attempted at
    ``arrival + backoff * 2^attempt`` up to ``max_retries`` times, after
    which the give-up is recorded on the engine's shed ledger — offered
    traffic is always accounted completed, shed, or rejected, never lost.
    ``on_step(engine, vclock, done)`` is called after every engine step
    (chaos-benchmark probe for per-step recovery tracking)."""
    trace = [(np.asarray(tok).reshape(-1), float(at), 0)
             for tok, at in requests]
    trace.sort(key=lambda p: p[1])
    pending = deque(trace)
    vclock = 0.0
    results: List[RequestResult] = []
    while pending or engine.has_work():
        if pending and not engine.has_work():
            vclock = max(vclock, pending[0][1])     # idle until next arrival
        retries = []
        while pending and pending[0][1] <= vclock:
            tok, at, attempt = pending.popleft()
            rid = engine.submit(tok, arrival=at, max_new_tokens=max_new_tokens)
            if rid >= 0:
                continue
            if retry_backoff_s > 0 and attempt < max_retries:
                retries.append((tok, at + retry_backoff_s * 2 ** attempt,
                                attempt + 1))
            else:
                engine.record_shed(-1, at, vclock, "rejected")
        if retries:
            pending.extend(retries)
            pending = deque(sorted(pending, key=lambda p: p[1]))
        done = engine.step(now=vclock, time_scale=time_scale)
        if engine.last_step_end is not None:
            vclock = max(vclock, engine.last_step_end)  # one stamp per batch
        elif pending:
            vclock = max(vclock, pending[0][1])     # nothing ran: skip ahead
        results.extend(done)
        if on_step is not None:
            on_step(engine, vclock, done)
    return results
