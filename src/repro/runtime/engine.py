"""Continuous-batching front end for the two-phase MoE server (§5/§6.2).

Requests enter a FIFO queue with arrival timestamps; each engine step forms
a micro-batch under a token budget (and a request cap), pads it to a
bucketed rectangle so jit caches stay small, and runs it through
``MoEServer.serve_batch`` — the plan-honoring distributed dispatch with a
cross-batch PlanCache, so phase-1 planning amortizes over traffic instead
of running per layer per batch.  Gating capacity is sized from *valid*
tokens (see ``MoEServer._valid_capacity``), so bucket padding never changes
a real request's dispatch.  Each request's rolling path-ID state is kept
(bounded) after completion: submitting a follow-up with ``prev_rid`` seeds
the next step's popularity estimation from where the last step left off.

Latency accounting supports both wall-clock serving (``submit`` stamps
arrivals from the engine clock) and open-loop trace replay (``simulate``):
virtual arrival times drive queueing delay while the measured wall time of
each step drives service time.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.runtime.server import LayerStats, MoEServer


@dataclass
class EngineConfig:
    max_batch_tokens: int = 1024   # token budget per micro-batch
    max_batch_requests: int = 16   # row cap per micro-batch
    pad_to_pow2: bool = True       # bucket batch rows to powers of two
    state_cache: int = 4096        # completed path states kept for follow-ups
    stats_window: int = 4096       # LayerStats retained for metrics


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                       # [S] token ids
    arrival: float                           # queue-entry timestamp
    path_state: Optional[np.ndarray] = None  # [S] rolling path ids


@dataclass
class RequestResult:
    rid: int
    logits: np.ndarray                       # [V] last-token logits
    arrival: float
    completion: float
    n_tokens: int

    @property
    def latency(self) -> float:
        return self.completion - self.arrival


class ServingEngine:
    """Queue -> micro-batch -> plan-cached distributed dispatch."""

    def __init__(self, server: MoEServer, ecfg: Optional[EngineConfig] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.server = server
        self.ecfg = ecfg or EngineConfig()
        self.clock = clock
        self._queue: Deque[Request] = deque()
        self._path_states: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._next_rid = 0
        self.layer_stats: Deque[LayerStats] = deque(
            maxlen=self.ecfg.stats_window)
        self._finetunes = 0
        self._layers_served = 0

    # --- queueing -----------------------------------------------------------
    def submit(self, tokens, arrival: Optional[float] = None,
               prev_rid: Optional[int] = None) -> int:
        """Enqueue one request; returns its id.  ``prev_rid`` names an
        earlier request of the same stream: the new request seeds its
        rolling path-ID state from that request's final state."""
        tokens = np.asarray(tokens).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        state = None if prev_rid is None else self.request_path_state(prev_rid)
        req = Request(rid, tokens,
                      self.clock() if arrival is None else arrival,
                      path_state=state)
        self._queue.append(req)
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def request_path_state(self, rid: int) -> Optional[np.ndarray]:
        for req in self._queue:             # still waiting: pre-step state
            if req.rid == rid:
                return req.path_state
        return self._path_states.get(rid)

    # --- micro-batch formation ---------------------------------------------
    def _form_microbatch(self) -> List[Request]:
        """FCFS under the token budget; always admits the queue head so an
        over-budget single request still makes progress."""
        ecfg = self.ecfg
        batch: List[Request] = []
        budget = ecfg.max_batch_tokens
        while self._queue and len(batch) < ecfg.max_batch_requests:
            nxt = self._queue[0]
            cost = nxt.tokens.shape[0]
            if batch and cost > budget:
                break
            batch.append(self._queue.popleft())
            budget -= cost
        return batch

    @staticmethod
    def _bucket_rows(n: int) -> int:
        return 1 << (n - 1).bit_length()

    def _remember_state(self, rid: int, state: np.ndarray) -> None:
        self._path_states[rid] = state
        while len(self._path_states) > self.ecfg.state_cache:
            self._path_states.popitem(last=False)

    # --- serving ------------------------------------------------------------
    def step(self, now: Optional[float] = None, time_scale: float = 1.0
             ) -> List[RequestResult]:
        """Serve one micro-batch from the queue; returns completed
        requests (empty when the queue is idle).  With ``now`` given,
        completions are stamped ``now + wall_service * time_scale``
        (virtual-clock replay); otherwise from the engine clock."""
        batch = self._form_microbatch()
        if not batch:
            return []
        b_real = len(batch)
        b = self._bucket_rows(b_real) if self.ecfg.pad_to_pow2 else b_real
        s = max(r.tokens.shape[0] for r in batch)
        tokens = np.zeros((b, s), np.int64)
        lengths = np.zeros((b,), np.int64)
        path_init = np.zeros((b, s), np.int64)
        for i, r in enumerate(batch):
            n = r.tokens.shape[0]
            tokens[i, :n] = r.tokens
            lengths[i] = n
            if r.path_state is not None:
                m = min(n, r.path_state.shape[0])
                path_init[i, :m] = r.path_state[:m]

        t0 = time.perf_counter()
        res = self.server.serve_batch(tokens, lengths=lengths,
                                      path_init=path_init)
        service = time.perf_counter() - t0
        self.layer_stats.extend(res.stats)
        self._finetunes += sum(s_.finetuned for s_ in res.stats)
        self._layers_served += len(res.stats)
        completion = self.clock() if now is None else now + service * time_scale

        out: List[RequestResult] = []
        for i, r in enumerate(batch):
            n = int(lengths[i])
            self._remember_state(r.rid, res.path_ids[i, :n].copy())
            out.append(RequestResult(r.rid, res.logits[i], r.arrival,
                                     completion, n))
        return out

    def run(self) -> List[RequestResult]:
        """Drain the queue in wall-clock mode."""
        results: List[RequestResult] = []
        while self._queue:
            results.extend(self.step())
        return results

    # --- metrics ------------------------------------------------------------
    @property
    def plan_reuse_rate(self) -> float:
        cache = self.server.plan_cache
        return cache.stats.reuse_rate if cache is not None else 0.0

    @property
    def finetune_rate(self) -> float:
        return self._finetunes / self._layers_served \
            if self._layers_served else 0.0


def simulate(engine: ServingEngine, requests, time_scale: float = 1.0
             ) -> List[RequestResult]:
    """Open-loop trace replay: ``requests`` is an iterable of
    (tokens, arrival_time) virtual-time pairs.  Queueing delay comes from
    the virtual clock; service time is the measured wall time of each step
    scaled by ``time_scale``.  Returns per-request results whose
    ``latency`` mixes both — the standard open-loop p50/p95 methodology."""
    trace = [(np.asarray(tok).reshape(-1), float(at)) for tok, at in requests]
    trace.sort(key=lambda p: p[1])
    vclock = 0.0
    i = 0
    results: List[RequestResult] = []
    while i < len(trace) or engine.pending():
        if not engine.pending():
            vclock = max(vclock, trace[i][1])       # idle until next arrival
        while i < len(trace) and trace[i][1] <= vclock:
            engine.submit(trace[i][0], arrival=trace[i][1])
            i += 1
        done = engine.step(now=vclock, time_scale=time_scale)
        if done:
            vclock = done[0].completion             # one stamp per batch
            results.extend(done)
    return results
