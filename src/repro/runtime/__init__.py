"""Runtime: fault-tolerant Trainer, the two-phase MoE Server, and the
continuous-batching ServingEngine front end."""
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.server import MoEServer, ServeResult, ServerConfig
from repro.runtime.engine import (EngineConfig, Request, RequestResult,
                                  ServingEngine, simulate)
