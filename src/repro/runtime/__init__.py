"""Runtime: fault-tolerant Trainer and the two-phase MoE Server."""
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.server import MoEServer, ServerConfig
