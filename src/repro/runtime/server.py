"""Two-phase MoE serving runtime (paper §5/§6.2).

Per MoE layer the Server:
  phase 1: estimates next-layer expert popularity from each token's sample
           path (PathProfile Ψ lookup — overlapped with compute on a real
           cluster), then *reuses the layer's cached PlacementPlan* while
           the estimate's top-2k set still matches the popularity the plan
           was built from (PlanCache); only on drift does it re-plan
           (Eq. 1 + FFD replication/packing);
  gate:    runs the actual gating network (a router matmul; the full MoE
           dispatch below re-derives the identical gating inside jit);
  phase 2: compares top-2k estimated vs actual experts; on deviation,
           re-plans from the actual popularity (blocking — the paper's
           ~23% fine-tune case) and refreshes the cache;
  dispatch: executes the MoE layer through the *distributed plan-honoring
           path* ``core.serving.serve_moe_layer`` — replica round-robin
           routing, packed experts, a2a to slot owners — under the final
           plan.  Device loads are additionally recorded for the latency
           model.

The Server drives real model weights (GroupParams stacks: the paper models,
mixtral, llama4) and produces exact logits plus per-layer scheduling stats.
``runtime.engine`` wraps it in a continuous-batching front end (request
queue, token-budget micro-batches, per-request path state).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.gating import capacity
from repro.core.placement import (PlacementPlan, PlanCache, identity_plan,
                                  needs_finetune, plan_placement)
from repro.core.popularity import PathProfile
from repro.core.serving import PlanArrays, dp_shard_count, serve_moe_layer
from repro.models import lm as lm_mod
from repro.models.attention import attention
from repro.models.layers import rms_norm


@dataclass
class ServerConfig:
    top_k: int = 1                 # paper: top-1 gating at inference
    path_len: int = 3
    max_pack: int = 4
    n_devices: int = 0             # 0 => n_experts (paper: 1 expert/device)
    use_estimation: bool = True    # ablation: False = schedule after gating
    use_finetuning: bool = True    # ablation: False = never fine-tune
    schedule_policy: str = "lina"  # lina | uniform (DeepSpeed baseline)
    plan_cache: bool = True        # reuse plans across batches until drift


@dataclass
class LayerStats:
    layer: int
    est_pop: np.ndarray
    actual_pop: np.ndarray
    finetuned: bool
    est_accurate: bool
    plan_reused: bool              # plan came from the cache (no re-plan)
    device_load: np.ndarray        # token share per device (actual workload)


class ServeResult(NamedTuple):
    logits: np.ndarray             # [B, V] last-valid-token logits
    stats: List[LayerStats]
    path_ids: np.ndarray           # [B, S] final rolling path state


class MoEServer:
    def __init__(self, cfg: ModelConfig, params, profile: PathProfile,
                 scfg: Optional[ServerConfig] = None, mesh=None):
        assert cfg.moe.enabled, "MoEServer serves MoE architectures"
        scfg = scfg or ServerConfig()
        self.cfg = cfg
        self.params = params
        self.profile = profile
        self.scfg = scfg
        self.mesh = mesh
        self.n_dev = scfg.n_devices or cfg.moe.n_experts
        self.every = cfg.moe.every
        self.plan_cache = PlanCache(top_k=scfg.top_k) if scfg.plan_cache \
            else None
        self._attn = jax.jit(self._attn_fn)
        self._gate = jax.jit(self._gate_fn)
        self._dispatch = jax.jit(self._dispatch_fn,
                                 static_argnames=("min_replicas", "cap"))
        self._ffn = jax.jit(partial(lm_mod._ffn_apply, ffn_type=cfg.ffn_type,
                                    mesh=None))

    # --- jitted layer pieces ----------------------------------------------
    def _attn_fn(self, gp, j, x):
        a_p = jax.tree.map(lambda a: a[j] if a is not None else None, gp.attn,
                           is_leaf=lambda a: a is None)
        h = rms_norm(x, gp.ln1[j], self.cfg.norm_eps)
        y, _ = attention(None, a_p, h, self.cfg)
        return x + y

    def _gate_fn(self, router, h2):
        logits = h2 @ router
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        _, idx = jax.lax.top_k(probs, self.scfg.top_k)
        return probs, idx.astype(jnp.int32)

    def _dispatch_fn(self, moe_p, h2, se, ro, nr, *, min_replicas: int,
                     cap: int):
        """The distributed MoE layer under the final plan: replica
        round-robin + packed experts via ``serve_moe_layer`` (shard_map;
        collapses to single-device collectives on the default mesh)."""
        plan = PlanArrays(se, ro, nr)
        y, _, _ = serve_moe_layer(self.mesh, h2, moe_p, self.cfg.moe, plan,
                                  ffn_type=self.cfg.ffn_type,
                                  top_k=self.scfg.top_k,
                                  min_replicas=min_replicas,
                                  cap_override=cap)
        return y

    def _valid_capacity(self, n_valid: int, n_total: int) -> int:
        """Per-device gating capacity sized from the *valid* token count so
        engine padding rows cannot change real tokens' dispatch (pad rows
        sort after real rows in slot order; with capacity fixed they can
        only be dropped, never displace)."""
        shards = dp_shard_count(self.mesh, n_total)
        return capacity(-(-n_valid // shards), self.cfg.moe.n_experts,
                        self.scfg.top_k, self.cfg.moe.capacity_factor)

    # --- planning ----------------------------------------------------------
    def _plan_layer(self, li: int, est: np.ndarray, actual: np.ndarray):
        """Phase 1 (cache-aware) + phase 2.  Returns
        (plan, finetuned, accurate, reused)."""
        cfg, scfg = self.cfg, self.scfg
        accurate = not needs_finetune(est, actual, scfg.top_k)
        reused = False
        finetuned = False
        if scfg.schedule_policy == "uniform":
            # the uniform layout is static: look up before building so a
            # hit skips plan construction entirely
            uniform = np.full((cfg.moe.n_experts,),
                              1.0 / cfg.moe.n_experts, np.float32)
            if self.plan_cache is not None:
                cached = self.plan_cache.lookup(li, uniform)
                if cached is not None:
                    return cached, False, accurate, True
            plan = identity_plan(cfg.moe.n_experts, self.n_dev,
                                 scfg.max_pack)
            if self.plan_cache is not None:
                self.plan_cache.store(li, plan)
            return plan, False, accurate, False

        # the popularity basis the final plan must honor: the estimate in
        # the common case, the realized popularity when phase 2 triggers
        # (or when estimation is ablated away entirely)
        if not scfg.use_estimation:
            basis, phase2 = actual, False
        elif scfg.use_finetuning and not accurate:
            basis, phase2 = actual, True
        else:
            basis, phase2 = est, False
        plan = None
        if self.plan_cache is not None:
            plan = self.plan_cache.lookup(li, basis)
            reused = plan is not None
        # a cache hit absorbs the phase-2 case: the blocking re-plan (the
        # paper's ~23% fine-tune cost) only happens when the basis drifted
        finetuned = phase2 and not reused
        if plan is None:
            plan = plan_placement(basis, self.n_dev, scfg.max_pack)
            if self.plan_cache is not None:
                self.plan_cache.store(li, plan)
        return plan, finetuned, accurate, reused

    # --- serving loop -------------------------------------------------------
    def serve(self, tokens: np.ndarray, lengths=None) -> tuple:
        """tokens: [B, S] -> (last logits [B, V], stats list[LayerStats])."""
        res = self.serve_batch(tokens, lengths=lengths)
        return res.logits, res.stats

    def serve_batch(self, tokens: np.ndarray, lengths=None,
                    path_init: Optional[np.ndarray] = None) -> ServeResult:
        """Serve one (micro-)batch through the full model.

        tokens:    [B, S] token ids (rows may be right-padded)
        lengths:   optional [B] valid-token counts; 0 marks an all-padding
                   row (engine batch-shape bucketing).  Padded positions
                   still flow through the network (static shapes) but are
                   excluded from popularity statistics, and each row's
                   logits are read at its last *valid* position.
        path_init: optional [B, S] rolling path-ID state from a previous
                   step of the same requests (engine-carried).
        """
        cfg, scfg = self.cfg, self.scfg
        tokens = np.asarray(tokens)
        b, s = tokens.shape
        if lengths is None:
            lengths = np.full((b,), s, np.int64)
        lengths = np.asarray(lengths, np.int64)
        params = lm_mod.cast_for_compute(cfg, self.params)
        x = params.embed[jnp.asarray(tokens)].astype(jnp.dtype(cfg.dtype))
        d = x.shape[-1]
        t = b * s
        valid = (np.arange(s)[None, :] < lengths[:, None]).reshape(t)
        path_ids = np.zeros((t,), np.int64) if path_init is None \
            else np.asarray(path_init, np.int64).reshape(t)
        stats: List[LayerStats] = []
        n_groups = cfg.n_layers // self.every
        moe_layer_idx = 0
        for g in range(n_groups):
            gp = jax.tree.map(lambda a: a[g] if a is not None else None,
                              self.params.stack, is_leaf=lambda a: a is None)
            gp = lm_mod.cast_for_compute(cfg, lm_mod.LMParams(
                params.embed, None, None, None, gp, params.final_norm, None)
            ).stack
            for j in range(self.every):
                x = self._attn(gp, j, x)
                h = rms_norm(x, gp.ln2[j], cfg.norm_eps)
                is_moe = j == self.every - 1
                if not is_moe:
                    ffn_p = jax.tree.map(lambda a: a[j] if a is not None else
                                         None, gp.ffn,
                                         is_leaf=lambda a: a is None) \
                        if gp.ffn is not None and gp.ffn.w_in.ndim > 2 else gp.ffn
                    x = x + self._ffn(ffn_p, h)
                    continue
                h2 = h.reshape(t, d)
                li = moe_layer_idx

                # phase 1: estimate ahead of gating
                if scfg.schedule_policy == "uniform" or \
                        not scfg.use_estimation or li < scfg.path_len:
                    est = np.full((cfg.moe.n_experts,),
                                  1.0 / cfg.moe.n_experts, np.float32)
                else:
                    est = self.profile.estimate_popularity(
                        li, path_ids[valid] if valid.any() else path_ids)

                _, idx = self._gate(gp.moe.router, h2)
                top1 = np.asarray(idx[:, 0])
                actual = np.bincount(top1, weights=valid.astype(np.float64),
                                     minlength=cfg.moe.n_experts)
                actual = actual / max(actual.sum(), 1.0)

                plan, finetuned, accurate, reused = \
                    self._plan_layer(li, est, actual)

                # dispatch under the final plan (distributed path);
                # capacity sized from valid tokens, not the padded batch
                y = self._dispatch(
                    gp.moe, h2, jnp.asarray(plan.slot_expert),
                    jnp.asarray(plan.replica_of),
                    jnp.asarray(plan.n_replicas),
                    min_replicas=int(plan.n_replicas.min()),
                    cap=self._valid_capacity(int(valid.sum()), t))
                moe_y = y.reshape(b, s, d)
                if gp.shared is not None:
                    moe_y = moe_y + self._ffn(gp.shared, h)
                x = x + moe_y

                # loads are always evaluated against the ACTUAL popularity —
                # the plan decides placement, the workload decides load
                stats.append(LayerStats(
                    li, np.asarray(est), np.asarray(actual), finetuned,
                    accurate, reused,
                    plan.device_load(actual.astype(np.float32))))
                path_ids = (path_ids * cfg.moe.n_experts + top1) \
                    % self.profile.n_buckets
                moe_layer_idx += 1
        x = rms_norm(x, lm_mod.cast_for_compute(cfg, self.params).final_norm,
                     cfg.norm_eps)
        last = np.maximum(lengths - 1, 0)
        x_last = np.asarray(x)[np.arange(b), last]
        logits = x_last @ np.asarray(lm_mod.unembed_weight(params))
        return ServeResult(np.asarray(logits), stats,
                           path_ids.reshape(b, s))


def profile_from_training(cfg: ModelConfig, params, batches,
                          path_len: int = 3, mesh=None) -> PathProfile:
    """Profiling stage (§5.2): replay data through the model, collect
    per-layer top-1 expert choices, accumulate Ψ tables."""
    n_moe = cfg.n_moe_layers
    prof = PathProfile(n_layers=n_moe, n_experts=cfg.moe.n_experts,
                       path_len=path_len)
    fwd = jax.jit(lambda p, b: lm_mod.forward_train(
        mesh, cfg, p, b, lina=False).expert_choices)
    for batch in batches:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        choices = np.asarray(fwd(params, b))       # [n_moe, T]
        prof.profile_batch(choices)
    return prof
