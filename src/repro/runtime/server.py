"""Two-phase MoE serving runtime (paper §5/§6.2).

Per MoE layer the Server:
  phase 1: estimates next-layer expert popularity from each token's sample
           path (PathProfile Ψ lookup — overlapped with compute on a real
           cluster), plans placement (Eq. 1 + FFD replication/packing);
  gate:    runs the actual gating network;
  phase 2: compares top-2k estimated vs actual experts; on deviation,
           re-plans from the actual popularity (blocking — the paper's
           ~23% fine-tune case);
  dispatch: executes the MoE layer; device loads under the final plan are
           recorded for the latency model (numerics are placement-
           independent — placement changes *time*, which benchmarks model
           with the v5e constants; the distributed plan-honoring dispatch
           itself is ``core.serving.serve_moe_layer``, exercised on a
           multi-device mesh in tests).

The Server drives real model weights (GroupParams stacks: the paper models,
mixtral, llama4) and produces exact logits plus per-layer scheduling stats.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.moe import expert_ffn
from repro.core.placement import (PlacementPlan, identity_plan,
                                  needs_finetune, plan_placement)
from repro.core.popularity import PathProfile
from repro.models import lm as lm_mod
from repro.models.attention import attention
from repro.models.layers import rms_norm


@dataclass
class ServerConfig:
    top_k: int = 1                 # paper: top-1 gating at inference
    path_len: int = 3
    max_pack: int = 4
    n_devices: int = 0             # 0 => n_experts (paper: 1 expert/device)
    use_estimation: bool = True    # ablation: False = schedule after gating
    use_finetuning: bool = True    # ablation: False = never fine-tune
    schedule_policy: str = "lina"  # lina | uniform (DeepSpeed baseline)


@dataclass
class LayerStats:
    layer: int
    est_pop: np.ndarray
    actual_pop: np.ndarray
    finetuned: bool
    est_accurate: bool
    device_load: np.ndarray        # estimated token share per device


class MoEServer:
    def __init__(self, cfg: ModelConfig, params, profile: PathProfile,
                 scfg: ServerConfig = ServerConfig(), mesh=None):
        assert cfg.moe.enabled, "MoEServer serves MoE architectures"
        self.cfg = cfg
        self.params = params
        self.profile = profile
        self.scfg = scfg
        self.mesh = mesh
        self.n_dev = scfg.n_devices or cfg.moe.n_experts
        self.every = cfg.moe.every
        self._attn = jax.jit(self._attn_fn)
        self._gate = jax.jit(self._gate_fn)
        self._moe = jax.jit(self._moe_fn)
        self._ffn = jax.jit(partial(lm_mod._ffn_apply, ffn_type=cfg.ffn_type,
                                    mesh=None))

    # --- jitted layer pieces ----------------------------------------------
    def _attn_fn(self, gp, j, x):
        a_p = jax.tree.map(lambda a: a[j] if a is not None else None, gp.attn,
                           is_leaf=lambda a: a is None)
        h = rms_norm(x, gp.ln1[j], self.cfg.norm_eps)
        y, _ = attention(None, a_p, h, self.cfg)
        return x + y

    def _gate_fn(self, router, h2):
        logits = h2 @ router
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        _, idx = jax.lax.top_k(probs, self.scfg.top_k)
        return probs, idx.astype(jnp.int32)

    def _moe_fn(self, moe_p, h2, probs):
        """Dense per-expert evaluation + gated combine (placement changes
        time, not values — loads are modeled from the plan separately)."""
        w, idx = jax.lax.top_k(probs, self.scfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        e = self.cfg.moe.n_experts
        onehot = jax.nn.one_hot(idx, e, dtype=h2.dtype)           # [T,k,E]
        xw = jnp.einsum("tke,tk->te", onehot, w.astype(h2.dtype))  # [T,E]
        xe_raw = jnp.broadcast_to(h2[None], (e, *h2.shape))
        ye = expert_ffn(moe_p.wi, moe_p.wu, moe_p.wo, xe_raw,
                        self.cfg.ffn_type)                        # [E,T,d]
        return jnp.einsum("te,etd->td", xw, ye)

    # --- serving loop -------------------------------------------------------
    def serve(self, tokens: np.ndarray) -> tuple:
        """tokens: [B, S] -> (last logits [B, V], stats list[LayerStats])."""
        cfg, scfg = self.cfg, self.scfg
        params = lm_mod.cast_for_compute(cfg, self.params)
        x = params.embed[jnp.asarray(tokens)].astype(jnp.dtype(cfg.dtype))
        b, s, d = x.shape
        t = b * s
        path_ids = np.zeros((t,), np.int64)
        stats = []
        n_groups = cfg.n_layers // self.every
        moe_layer_idx = 0
        for g in range(n_groups):
            gp = jax.tree.map(lambda a: a[g] if a is not None else None,
                              self.params.stack, is_leaf=lambda a: a is None)
            gp = lm_mod.cast_for_compute(cfg, lm_mod.LMParams(
                params.embed, None, None, None, gp, params.final_norm, None)
            ).stack
            for j in range(self.every):
                x = self._attn(gp, j, x)
                h = rms_norm(x, gp.ln2[j], cfg.norm_eps)
                is_moe = j == self.every - 1
                if not is_moe:
                    ffn_p = jax.tree.map(lambda a: a[j] if a is not None else
                                         None, gp.ffn,
                                         is_leaf=lambda a: a is None) \
                        if gp.ffn is not None and gp.ffn.w_in.ndim > 2 else gp.ffn
                    x = x + self._ffn(ffn_p, h)
                    continue
                h2 = h.reshape(t, d)
                li = moe_layer_idx

                # phase 1: estimate + plan before gating
                if scfg.schedule_policy == "uniform":
                    est = np.full((cfg.moe.n_experts,),
                                  1.0 / cfg.moe.n_experts, np.float32)
                elif scfg.use_estimation and li >= scfg.path_len:
                    est = self.profile.estimate_popularity(li, path_ids)
                else:
                    est = np.full((cfg.moe.n_experts,),
                                  1.0 / cfg.moe.n_experts, np.float32)

                probs, idx = self._gate(gp.moe.router, h2)
                top1 = np.asarray(idx[:, 0])
                actual = np.bincount(top1, minlength=cfg.moe.n_experts
                                     ).astype(np.float64)
                actual = actual / max(actual.sum(), 1.0)

                finetuned = False
                accurate = not needs_finetune(est, actual, scfg.top_k)
                if scfg.schedule_policy == "uniform":
                    plan = identity_plan(cfg.moe.n_experts, self.n_dev,
                                         scfg.max_pack)
                else:
                    basis = est
                    if not scfg.use_estimation:
                        basis, finetuned = actual, False
                    plan = plan_placement(basis, self.n_dev, scfg.max_pack)
                    if scfg.use_estimation and scfg.use_finetuning and \
                            not accurate:
                        plan = plan_placement(actual, self.n_dev,
                                              scfg.max_pack)
                        finetuned = True
                # loads are always evaluated against the ACTUAL popularity —
                # the plan decides placement, the workload decides load
                plan = PlacementPlan(plan.slot_expert, plan.replica_of,
                                     plan.n_replicas,
                                     actual.astype(np.float32))

                y = self._moe(gp.moe, h2, probs)
                moe_y = y.reshape(b, s, d)
                if gp.shared is not None:
                    moe_y = moe_y + self._ffn(gp.shared, h)
                x = x + moe_y

                stats.append(LayerStats(li, np.asarray(est),
                                        np.asarray(actual), finetuned,
                                        accurate, plan.device_load()))
                path_ids = (path_ids * cfg.moe.n_experts + top1) \
                    % self.profile.n_buckets
                moe_layer_idx += 1
        x = rms_norm(x, lm_mod.cast_for_compute(cfg, self.params).final_norm,
                     cfg.norm_eps)
        logits = x[:, -1] @ lm_mod.unembed_weight(params)
        return np.asarray(logits), stats


def profile_from_training(cfg: ModelConfig, params, batches,
                          path_len: int = 3, mesh=None) -> PathProfile:
    """Profiling stage (§5.2): replay data through the model, collect
    per-layer top-1 expert choices, accumulate Ψ tables."""
    n_moe = cfg.n_moe_layers
    prof = PathProfile(n_layers=n_moe, n_experts=cfg.moe.n_experts,
                       path_len=path_len)
    fwd = jax.jit(lambda p, b: lm_mod.forward_train(
        mesh, cfg, p, b, lina=False).expert_choices)
    for batch in batches:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        choices = np.asarray(fwd(params, b))       # [n_moe, T]
        prof.profile_batch(choices)
    return prof
