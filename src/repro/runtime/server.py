"""Two-phase MoE serving runtime (paper §5/§6.2).

Per MoE layer the Server:
  phase 1: estimates next-layer expert popularity from each token's sample
           path (PathProfile Ψ lookup — overlapped with compute on a real
           cluster), then *reuses the layer's cached PlacementPlan* while
           the estimate's top-2k set still matches the popularity the plan
           was built from (PlanCache); only on drift does it re-plan
           (Eq. 1 + FFD replication/packing);
  gate:    runs the actual gating network (a router matmul; the full MoE
           dispatch below re-derives the identical gating inside jit);
  phase 2: compares top-2k estimated vs actual experts; on deviation,
           re-plans from the actual popularity (blocking — the paper's
           ~23% fine-tune case) and refreshes the cache;
  dispatch: executes the MoE layer through the *distributed plan-honoring
           path* ``core.serving.serve_moe_layer`` — replica round-robin
           routing, packed experts, a2a to slot owners — under the final
           plan.  Device loads are additionally recorded for the latency
           model.

That per-layer core (``_serve_moe``) backs three entry points:

  ``serve_batch``    full-sequence scoring (no cache; the PR-1 path)
  ``prefill_batch``  full-sequence + KV-cache capture: returns last-token
                     logits, an ``LMCache`` sized to ``cache_len`` and the
                     rolling path-ID state, so generation can continue
                     incrementally;
  ``decode_batch``   ONE token per request against the cache — the paper's
                     latency-bound decoding regime (§5): tiny batches,
                     popularity skew, per-layer plan-scheduled dispatch.

The Server drives real model weights (GroupParams stacks: the paper models,
mixtral, llama4) and produces exact logits plus per-layer scheduling stats.
``runtime.engine`` wraps it in a continuous-batching front end (request
queue, prefill/decode lifecycle, token-budget micro-batches, per-request
path + KV state).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.gating import capacity
from repro.core.placement import (PlacementPlan, PlanCache, identity_plan,
                                  needs_finetune, plan_from_replicas,
                                  plan_placement, route_weights)
from repro.core.popularity import PathProfile
from repro.core.serving import (PlanArrays, dp_shard_count,
                                mask_dead_route_weights,
                                replica_token_counts, serve_moe_layer,
                                slot_capacity)
from repro.models import lm as lm_mod
from repro.models.attention import KVCache, attention, decode_attention
from repro.models.layers import rms_norm
from repro.models.lm import LMCache
from repro.obs import ObsContext


@dataclass
class ServerConfig:
    top_k: int = 1                 # paper: top-1 gating at inference
    path_len: int = 3
    max_pack: int = 4
    n_devices: int = 0             # 0 => n_experts (paper: 1 expert/device)
    use_estimation: bool = True    # ablation: False = schedule after gating
    use_finetuning: bool = True    # ablation: False = never fine-tune
    schedule_policy: str = "lina"  # lina | uniform (DeepSpeed baseline)
    plan_cache: bool = True        # reuse plans across batches until drift
    route_mode: str = "weighted"   # weighted (§5 histogram split) |
    #                                round_robin (positional ablation)
    phase2_timeout_s: float = 0.0  # watchdog: a phase-2 re-plan slower than
    #                                this suppresses further fine-tunes for
    #                                ``phase2_backoff`` plan calls (0 = off)
    phase2_backoff: int = 8


@dataclass
class LayerStats:
    layer: int
    est_pop: np.ndarray
    actual_pop: np.ndarray
    finetuned: bool
    est_accurate: bool
    plan_reused: bool              # plan came from the cache (no re-plan)
    device_load: np.ndarray        # token share per device (actual workload)
    n_tokens: int = 0              # valid tokens this layer dispatched
    replica_load: Optional[np.ndarray] = None
    #                                [n_slots] realized valid-token count per
    #                                (device, sub-slot) after replica routing
    #                                (host mirror of the device split)


class ServeResult(NamedTuple):
    logits: np.ndarray             # [B, V] last-valid-token logits
    stats: List[LayerStats]
    path_ids: np.ndarray           # [B, S] final rolling path state


class PrefillResult(NamedTuple):
    logits: np.ndarray             # [B, V] last-valid-token logits
    stats: List[LayerStats]
    path_ids: np.ndarray           # [B, S] final rolling path state
    cache: LMCache                 # KV cache sized to cache_len, pos=lengths


class DecodeResult(NamedTuple):
    logits: np.ndarray             # [B, V] next-token logits
    stats: List[LayerStats]
    path_state: np.ndarray         # [B] rolling path state after this token
    cache: LMCache                 # updated KV cache, pos advanced by 1


class MoEServer:
    def __init__(self, cfg: ModelConfig, params, profile: PathProfile,
                 scfg: Optional[ServerConfig] = None, mesh=None,
                 obs: Optional[ObsContext] = None):
        assert cfg.moe.enabled, "MoEServer serves MoE architectures"
        scfg = scfg or ServerConfig()
        self.cfg = cfg
        self.params = params
        self.profile = profile
        self.scfg = scfg
        self.mesh = mesh
        # shared observability context: ``ServingEngine`` installs its own
        # here when given one, so one flag traces the whole serving stack
        self.obs = obs or ObsContext.disabled()
        self.n_dev = scfg.n_devices or cfg.moe.n_experts
        self.every = cfg.moe.every
        self.plan_cache = PlanCache(top_k=scfg.top_k) if scfg.plan_cache \
            else None
        self._attn = jax.jit(self._attn_fn)
        self._attn_dec = jax.jit(self._attn_dec_fn)
        self._gate = jax.jit(self._gate_fn)
        self._dispatch = jax.jit(self._dispatch_fn,
                                 static_argnames=("min_replicas", "cap"))
        self._ffn = jax.jit(partial(lm_mod._ffn_apply, ffn_type=cfg.ffn_type,
                                    mesh=None))
        # weights are static across requests: cast once, slice layer groups
        # once, keep the unembed matrix device-resident — incremental decode
        # calls this machinery once per generated token, so per-call casts
        # and host matmuls would dominate TPOT
        self._cparams = lm_mod.cast_for_compute(cfg, params)
        self._w_unembed = jnp.asarray(lm_mod.unembed_weight(self._cparams))
        self._gp_cache: dict = {}
        self._plan_arrays: dict = {}
        # controller-published per-layer plans (repro.sched): while a layer
        # has an override the per-batch planner (phase 1 + phase 2) is
        # bypassed for it — the control loop owns placement at its own
        # cadence instead of per micro-batch
        self._plan_override: dict = {}
        self._override_fresh: set = set()
        # --- resilience state (repro.resilience) ---
        # devices masked out of planning and routing; fault_hook, when set,
        # is called as fault_hook("plan", layer) before each primary plan
        # build (the injection point for planner-crash faults)
        self.dead_devices: set = set()
        self.fault_hook = None
        self.degrade_stats: dict = {"planner_errors": 0, "phase2_timeouts": 0,
                                    "emergency_replans": 0}
        self._phase2_suppress = 0

    # --- adaptive scheduling (repro.sched) ---------------------------------
    def publish_plans(self, plans: dict) -> None:
        """Install controller-published plans ({layer: PlacementPlan}).

        Takes effect at the next micro-batch; in-flight decode state (KV
        caches, rolling path ids) is untouched — plans move experts across
        devices, they do not change the math (see
        ``test_engine_plan_swap_mid_decode_is_transparent``)."""
        self._plan_override.update(plans)
        self._override_fresh.update(plans.keys())

    # --- graceful degradation (repro.resilience) ---------------------------
    def fail_devices(self, devices) -> None:
        """Mask failed devices out of routing and planning, without touching
        in-flight decode state.

        Three rungs, cheapest first: (1) every served plan's route weights
        get their dead-replica columns zeroed (``_plan_device`` re-applies
        ``mask_dead_route_weights`` on upload — zero-migration, the kernel
        simply stops sending tokens there); (2) cached plans that placed an
        expert on a dead device are invalidated so the next batch re-plans
        under the mask; (3) a controller-published override plan that left
        some expert with NO surviving replica is emergency-rebuilt in place
        (incremental ``plan_from_replicas`` keeps surviving replicas where
        they are)."""
        devs = {int(d) for d in devices if 0 <= d < self.n_dev}
        if not devs - self.dead_devices:
            return
        self.dead_devices |= devs
        self._plan_arrays.clear()      # route-weight mask must re-apply
        if self.plan_cache is not None:
            self.plan_cache.invalidate_devices(self.dead_devices)
        rebuilt = {}
        for li, plan in self._plan_override.items():
            if self._plan_orphaned(plan):
                rebuilt[li] = plan_from_replicas(
                    plan.popularity, plan.n_replicas, self.n_dev,
                    max_pack=self.scfg.max_pack,
                    rep_width=plan.replica_of.shape[1], prev=plan,
                    dead_devices=self.dead_devices)
        if rebuilt:
            self.degrade_stats["emergency_replans"] += len(rebuilt)
            self.obs.metrics.counter(
                "server_degrade_total",
                kind="emergency_replan").inc(len(rebuilt))
            self.publish_plans(rebuilt)

    def revive_devices(self, devices) -> None:
        """Return repaired devices to the pool; plans re-expand onto them at
        the next re-plan (cache drift / controller cadence)."""
        self.dead_devices -= {int(d) for d in devices}
        self._plan_arrays.clear()

    def _plan_orphaned(self, plan: PlacementPlan) -> bool:
        """True iff some expert's every live replica sits on a dead device
        (zero-weight masking alone would drop its tokens)."""
        if not self.dead_devices:
            return False
        ro = np.asarray(plan.replica_of)
        live = (np.arange(ro.shape[1])[None, :]
                < np.clip(plan.n_replicas, 1, ro.shape[1])[:, None]) \
            & (ro >= 0)
        on_dead = np.zeros(ro.shape, bool)
        dev = np.where(live, ro // plan.max_pack, -1)
        for d in self.dead_devices:
            on_dead |= dev == d
        return bool((live & ~on_dead).sum(1).min() == 0)

    def warmup(self, *, seqs=(), rows=(1,), min_replicas_grid=(1, 2),
               max_new_tokens: int = 8) -> int:
        """Pre-trace the jitted serve paths so neither the first request nor
        a plan swap to an already-seen replica count is compile-dominated.

        Two grids:
          - full prefill (+ one decode step) at each prompt length in
            ``seqs`` with a single-row batch — the first-request p95 path;
          - the plan-honoring dispatch at every (decode row-bucket, cap,
            min_replicas, replica-table width) combination reachable from
            ``rows`` x ``min_replicas_grid`` — the shapes a controller plan
            swap or a new decode-batch bucket would otherwise compile
            inside a timed step.

        Plan-cache contents/stats and published overrides are restored, so
        warm-up leaves no scheduling trace.  Returns the number of traced
        calls.
        """
        import dataclasses as _dc

        cache = self.plan_cache
        saved_cache = (dict(cache._plans),
                       _dc.replace(cache.stats)) if cache is not None else None
        saved_ov = (dict(self._plan_override), set(self._override_fresh))
        traced = 0
        try:
            for s in seqs:
                pre = self.prefill_batch(np.zeros((1, int(s)), np.int64),
                                         cache_len=int(s) + max_new_tokens)
                traced += 1
                if max_new_tokens:
                    self.decode_batch(np.zeros((1,), np.int64), pre.cache,
                                      np.zeros((1,), np.int64))
                    traced += 1
            traced += self._warmup_dispatch(rows, min_replicas_grid)
        finally:
            if saved_cache is not None:
                cache._plans.clear()
                cache._plans.update(saved_cache[0])
                cache.stats.hits = saved_cache[1].hits
                cache.stats.misses = saved_cache[1].misses
                cache.stats.invalidations = saved_cache[1].invalidations
            self._plan_override = saved_ov[0]
            self._override_fresh = saved_ov[1]
        return traced

    def _warmup_dispatch(self, rows, min_replicas_grid) -> int:
        """Compile ``_dispatch`` for the (bucket, cap, min_replicas, width)
        grid; dedupes combinations that collapse to the same static key."""
        from repro.core.placement import plan_from_replicas

        cfg = self.cfg
        gp = self._group_params(0)
        combos = set()
        for n_valid in sorted(set(int(r) for r in rows)):
            bucket = 1 << (n_valid - 1).bit_length()
            cap = self._valid_capacity(n_valid, bucket)
            for r in min_replicas_grid:
                r = int(min(r, (self.n_dev * self.scfg.max_pack)
                            // cfg.moe.n_experts, self.n_dev))
                if r < 1:
                    r = 1
                # controller plans carry an n_dev-wide replica table, the
                # per-batch planner a max_pack-wide one — trace both
                for width in {self.n_dev, self.scfg.max_pack}:
                    combos.add((bucket, cap, r, width))
        for bucket, cap, r, width in sorted(combos):
            plan = plan_from_replicas(
                np.full((cfg.moe.n_experts,), 1.0 / cfg.moe.n_experts),
                np.full((cfg.moe.n_experts,), r, np.int64),
                self.n_dev, max_pack=self.scfg.max_pack, rep_width=width)
            se, ro, nr, rw = self._plan_device(plan)
            h2 = jnp.zeros((bucket, cfg.d_model), jnp.dtype(cfg.dtype))
            jax.block_until_ready(self._dispatch(
                gp.moe, h2, se, ro, nr, rw,
                min_replicas=int(plan.n_replicas.min()), cap=cap))
        return len(combos)

    # --- jitted layer pieces ----------------------------------------------
    def _attn_fn(self, gp, j, x):
        """Full-sequence attention block; also returns the K/V projections
        so prefill can populate the decode cache for free."""
        a_p = jax.tree.map(lambda a: a[j] if a is not None else None, gp.attn,
                           is_leaf=lambda a: a is None)
        h = rms_norm(x, gp.ln1[j], self.cfg.norm_eps)
        y, kv = attention(None, a_p, h, self.cfg)
        return x + y, kv.k, kv.v

    def _attn_dec_fn(self, gp, j, x, k, v, pos):
        """Single-token attention block against the KV cache.  x: [B,1,d];
        k/v: [B, S_cap, KV, hd]; pos: [B] absolute positions."""
        a_p = jax.tree.map(lambda a: a[j] if a is not None else None, gp.attn,
                           is_leaf=lambda a: a is None)
        h = rms_norm(x, gp.ln1[j], self.cfg.norm_eps)
        y, kv = decode_attention(None, a_p, h, KVCache(k, v), pos, self.cfg)
        return x + y, kv.k, kv.v

    def _gate_fn(self, router, h2):
        logits = h2 @ router
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        _, idx = jax.lax.top_k(probs, self.scfg.top_k)
        return probs, idx.astype(jnp.int32)

    def _dispatch_fn(self, moe_p, h2, se, ro, nr, rw, *, min_replicas: int,
                     cap: int):
        """The distributed MoE layer under the final plan: weighted (or
        round-robin) replica split + packed experts via ``serve_moe_layer``
        (shard_map; collapses to single-device collectives on the default
        mesh)."""
        plan = PlanArrays(se, ro, nr, rw)
        y, _, _ = serve_moe_layer(self.mesh, h2, moe_p, self.cfg.moe, plan,
                                  ffn_type=self.cfg.ffn_type,
                                  top_k=self.scfg.top_k,
                                  min_replicas=min_replicas,
                                  cap_override=cap,
                                  route_mode=self.scfg.route_mode)
        return y

    def _valid_capacity(self, n_valid: int, n_total: int) -> int:
        """Per-device gating capacity sized from the *valid* token count so
        engine padding rows cannot change real tokens' dispatch (pad rows
        sort after real rows in slot order; with capacity fixed they can
        only be dropped, never displace)."""
        shards = dp_shard_count(self.mesh, n_total)
        return capacity(-(-n_valid // shards), self.cfg.moe.n_experts,
                        self.scfg.top_k, self.cfg.moe.capacity_factor)

    # --- planning ----------------------------------------------------------
    def _plan_layer(self, li: int, est: np.ndarray, actual: np.ndarray):
        """Phase 1 (cache-aware) + phase 2.  Returns
        (plan, finetuned, accurate, reused)."""
        cfg, scfg = self.cfg, self.scfg
        met = self.obs.metrics
        accurate = not needs_finetune(est, actual, scfg.top_k)
        reused = False
        finetuned = False
        override = self._plan_override.get(li)
        if override is not None:
            # the control loop owns this layer's placement: no per-batch
            # re-plan, no blocking phase-2 — drift is handled at the
            # controller's cadence.  ``reused`` is False exactly once per
            # publish (the swap itself), True while the plan is live.
            fresh = li in self._override_fresh
            self._override_fresh.discard(li)
            met.counter("server_plan_lookup_total", result="override").inc()
            return override, False, accurate, not fresh
        if scfg.schedule_policy == "uniform":
            # the uniform layout is static: look up before building so a
            # hit skips plan construction entirely
            uniform = np.full((cfg.moe.n_experts,),
                              1.0 / cfg.moe.n_experts, np.float32)
            if self.plan_cache is not None:
                with self.obs.tracer.span("plan.lookup", layer=li):
                    cached = self.plan_cache.lookup(li, uniform)
                if cached is not None:
                    met.counter("server_plan_lookup_total",
                                result="hit").inc()
                    return cached, False, accurate, True
            met.counter("server_plan_lookup_total", result="miss").inc()
            plan = identity_plan(cfg.moe.n_experts, self.n_dev,
                                 scfg.max_pack)
            if self.plan_cache is not None:
                self.plan_cache.store(li, plan)
            return plan, False, accurate, False

        # the popularity basis the final plan must honor: the estimate in
        # the common case, the realized popularity when phase 2 triggers
        # (or when estimation is ablated away entirely).  The watchdog's
        # backoff window suppresses the blocking phase-2 re-plan and serves
        # from the phase-1 estimate instead.
        suppressed = self._phase2_suppress > 0
        if suppressed:
            self._phase2_suppress -= 1
        if not scfg.use_estimation:
            basis, phase2 = actual, False
        elif scfg.use_finetuning and not accurate and not suppressed:
            basis, phase2 = actual, True
        else:
            basis, phase2 = est, False
        plan = None
        if self.plan_cache is not None:
            with self.obs.tracer.span("plan.lookup", layer=li):
                plan = self.plan_cache.lookup(li, basis)
            reused = plan is not None
        met.counter("server_plan_lookup_total",
                    result="hit" if reused else "miss").inc()
        # a cache hit absorbs the phase-2 case: the blocking re-plan (the
        # paper's ~23% fine-tune cost) only happens when the basis drifted
        finetuned = phase2 and not reused
        if plan is None:
            plan = self._build_plan(li, basis, est, phase2)
            if self.plan_cache is not None:
                self.plan_cache.store(li, plan)
        return plan, finetuned, accurate, reused

    def _build_plan(self, li: int, basis: np.ndarray, est: np.ndarray,
                    phase2: bool) -> PlacementPlan:
        """Plan build wrapped in the phase-2 watchdog: a planner exception
        falls back down a degradation ladder (phase-1 estimate, then the
        masked uniform layout) instead of failing the batch, and a phase-2
        build slower than ``phase2_timeout_s`` suppresses further
        fine-tunes for ``phase2_backoff`` plan calls.  Either event arms
        the backoff and bumps ``degrade_stats``."""
        scfg = self.scfg
        met = self.obs.metrics
        # the watchdog stopwatch doubles as the phase-2 span: ``timed``
        # always measures (the timeout decision is functional), and records
        # a ``phase2.finetune`` / ``plan.build`` span when tracing is on
        sw = self.obs.tracer.timed(
            "phase2.finetune" if phase2 else "plan.build", layer=li)
        try:
            with sw:
                if self.fault_hook is not None:
                    self.fault_hook("plan", li)
                plan = plan_placement(basis, self.n_dev, scfg.max_pack,
                                      dead_devices=self.dead_devices)
        except Exception:
            self.degrade_stats["planner_errors"] += 1
            met.counter("server_degrade_total", kind="planner_error").inc()
            self._phase2_suppress = max(self._phase2_suppress,
                                        scfg.phase2_backoff)
            try:
                return plan_placement(est, self.n_dev, scfg.max_pack,
                                      dead_devices=self.dead_devices)
            except Exception:
                e = self.cfg.moe.n_experts
                return plan_from_replicas(
                    np.full((e,), 1.0 / e), np.ones((e,), np.int64),
                    self.n_dev, max_pack=scfg.max_pack,
                    dead_devices=self.dead_devices)
        if phase2 and scfg.phase2_timeout_s > 0 and \
                sw.dt > scfg.phase2_timeout_s:
            self.degrade_stats["phase2_timeouts"] += 1
            met.counter("server_degrade_total", kind="phase2_timeout").inc()
            self._phase2_suppress = scfg.phase2_backoff
        return plan

    # --- the shared per-layer two-phase core -------------------------------
    def _serve_moe(self, li: int, gp, h2, valid: np.ndarray,
                   path_ids: np.ndarray, has_state: bool):
        """Phase-1 estimate -> PlanCache lookup -> gate -> phase-2
        fine-tune on drift -> plan-honoring dispatch, for one MoE layer.

        h2: [T, d] hidden states; valid: [T] bool; path_ids: [T] rolling
        path hashes.  ``has_state`` marks carried path state (incremental
        decode), which lets early layers use the profile instead of the
        uniform cold-start estimate.  Returns (y [T, d], top1 [T], stats).
        """
        cfg, scfg = self.cfg, self.scfg
        tr = self.obs.tracer
        with tr.span("server.layer", layer=li) as lsp:
            with tr.span("phase1.estimate"):
                override = self._plan_override.get(li)
                if override is not None:
                    # controller-owned layer: the plan's own popularity basis
                    # (the telemetry EWMA it was built from) stands in for
                    # the per-batch Ψ estimate — no per-token profile lookup
                    # on the hot path
                    est = np.asarray(override.popularity, np.float32)
                elif scfg.schedule_policy == "uniform" or \
                        not scfg.use_estimation or \
                        (li < scfg.path_len and not has_state):
                    est = np.full((cfg.moe.n_experts,),
                                  1.0 / cfg.moe.n_experts, np.float32)
                else:
                    est = self.profile.estimate_popularity(
                        li, path_ids[valid] if valid.any() else path_ids)

            with tr.span("gate"):
                _, idx = self._gate(gp.moe.router, h2)
                top1 = np.asarray(idx[:, 0])
                actual = np.bincount(top1, weights=valid.astype(np.float64),
                                     minlength=cfg.moe.n_experts)
                actual = actual / max(actual.sum(), 1.0)

            plan, finetuned, accurate, reused = self._plan_layer(li, est,
                                                                 actual)

            with tr.span("dispatch"):
                # dispatch under the final plan (distributed path); capacity
                # sized from valid tokens, not the padded batch
                cap = self._valid_capacity(int(valid.sum()), h2.shape[0])
                min_rep = int(plan.n_replicas.min())
                se, ro, nr, rw = self._plan_device(plan)
                y = self._dispatch(gp.moe, h2, se, ro, nr, rw,
                                   min_replicas=min_rep, cap=cap)

                # host mirror of the replica split: realized valid-token
                # count per (device, sub-slot) — what the telemetry
                # bus/controller observes as post-routing imbalance
                rep_load = replica_token_counts(
                    np.asarray(idx), self._host_plan(plan), cap,
                    slot_capacity(cap, min_rep), valid=valid,
                    dp_shards=dp_shard_count(self.mesh, h2.shape[0]),
                    route_mode=scfg.route_mode)
            lsp.set(finetuned=finetuned, reused=reused, accurate=accurate)

        met = self.obs.metrics
        met.counter("server_layers_served_total").inc()
        if finetuned:
            met.counter("server_phase2_finetunes_total").inc()

        # loads are always evaluated against the ACTUAL popularity — the
        # plan decides placement, the workload decides load
        stat = LayerStats(li, np.asarray(est), np.asarray(actual), finetuned,
                          accurate, reused,
                          plan.device_load(actual.astype(np.float32)),
                          n_tokens=int(valid.sum()),
                          replica_load=rep_load)
        return y, top1, stat

    def _plan_device(self, plan: PlacementPlan):
        """Device-resident plan arrays, cached per plan object — the
        PlanCache keeps plan identity stable across batches/steps, so the
        host->device upload (and the route-weight IPF) happens once per
        (layer, popularity regime)."""
        ent = self._plan_arrays.get(id(plan))
        if ent is None or ent[0] is not plan:
            if len(self._plan_arrays) > 256:
                self._plan_arrays.clear()
            host_rw = route_weights(plan)
            if self.dead_devices:
                # degradation rung 1: zero-migration re-route — dead-replica
                # columns drop to weight 0 so the weighted split sends them
                # nothing (``fail_devices`` cleared this cache to re-apply)
                host_rw = np.asarray(mask_dead_route_weights(
                    host_rw, plan.replica_of, plan.max_pack,
                    self.dead_devices, xp=np), np.float32)
            ent = (plan, jnp.asarray(plan.slot_expert),
                   jnp.asarray(plan.replica_of), jnp.asarray(plan.n_replicas),
                   jnp.asarray(host_rw),
                   PlanArrays(plan.slot_expert, plan.replica_of,
                              plan.n_replicas, host_rw))
            self._plan_arrays[id(plan)] = ent
        return ent[1], ent[2], ent[3], ent[4]

    def _host_plan(self, plan: PlacementPlan) -> PlanArrays:
        """Host-side (numpy-leaf) PlanArrays for ``plan``, sharing the
        cached route-weight table with ``_plan_device``."""
        self._plan_device(plan)
        return self._plan_arrays[id(plan)][5]

    def _group_params(self, g):
        gp = self._gp_cache.get(g)
        if gp is None:
            gp = jax.tree.map(lambda a: a[g] if a is not None else None,
                              self._cparams.stack, is_leaf=lambda a: a is None)
            self._gp_cache[g] = gp
        return gp

    # --- serving loop -------------------------------------------------------
    def serve(self, tokens: np.ndarray, lengths=None) -> tuple:
        """tokens: [B, S] -> (last logits [B, V], stats list[LayerStats])."""
        res = self.serve_batch(tokens, lengths=lengths)
        return res.logits, res.stats

    def serve_batch(self, tokens: np.ndarray, lengths=None,
                    path_init: Optional[np.ndarray] = None) -> ServeResult:
        """Serve one (micro-)batch through the full model (no cache).

        tokens:    [B, S] token ids (rows may be right-padded)
        lengths:   optional [B] valid-token counts; 0 marks an all-padding
                   row (engine batch-shape bucketing).  Padded positions
                   still flow through the network (static shapes) but are
                   excluded from popularity statistics, and each row's
                   logits are read at its last *valid* position.
        path_init: optional [B, S] rolling path-ID state from a previous
                   step of the same requests (engine-carried).
        """
        logits, stats, path_ids, _ = self._forward(tokens, lengths, path_init,
                                                   cache_len=0)
        return ServeResult(logits, stats, path_ids)

    def prefill_batch(self, tokens: np.ndarray, lengths=None,
                      path_init: Optional[np.ndarray] = None,
                      cache_len: Optional[int] = None) -> PrefillResult:
        """serve_batch + KV-cache capture: the prompt phase of generation.

        ``cache_len`` sizes the per-row cache capacity (>= S; pass
        prompt_len + max_new_tokens so decode never overflows).  The
        returned cache's ``pos`` is each row's valid length, so
        ``decode_batch`` continues exactly where the prompt ended.
        """
        s = np.asarray(tokens).shape[1]
        cache_len = max(cache_len or s, s)
        # the incremental path writes the cache linearly (no ring); a
        # sliding-window model whose context exceeded the window would
        # silently diverge from full re-prefill — reject it loudly
        if self.cfg.sliding_window and cache_len > self.cfg.sliding_window:
            raise NotImplementedError(
                "incremental decode does not support sliding-window "
                f"contexts beyond the window ({cache_len} > "
                f"{self.cfg.sliding_window})")
        logits, stats, path_ids, cache = self._forward(
            tokens, lengths, path_init, cache_len=cache_len)
        return PrefillResult(logits, stats, path_ids, cache)

    def _walk_stack(self, x, *, attn, valid, path_ids, has_state, shape):
        """The group/layer walk shared by full-sequence forward and
        incremental decode: attention (via ``attn(gp, j, x) ->
        (x, k_j, v_j)``; k_j None = no cache capture), dense FFN for
        non-MoE sublayers, and the two-phase MoE core for MoE sublayers.
        ``shape`` is the (b, s) token grid of ``x``.  Returns
        (x, stats, path_ids, ks, vs) with ks/vs per-group stacks."""
        cfg = self.cfg
        b, s = shape
        t = b * s
        d = x.shape[-1]
        stats: List[LayerStats] = []
        ks: List[jax.Array] = []
        vs: List[jax.Array] = []
        n_groups = cfg.n_layers // self.every
        moe_layer_idx = 0
        for g in range(n_groups):
            gp = self._group_params(g)
            ks_g, vs_g = [], []
            for j in range(self.every):
                x, k_j, v_j = attn(gp, j, x)
                if k_j is not None:
                    ks_g.append(k_j)
                    vs_g.append(v_j)
                h = rms_norm(x, gp.ln2[j], cfg.norm_eps)
                is_moe = j == self.every - 1
                if not is_moe:
                    ffn_p = jax.tree.map(lambda a: a[j] if a is not None else
                                         None, gp.ffn,
                                         is_leaf=lambda a: a is None) \
                        if gp.ffn is not None and gp.ffn.w_in.ndim > 2 else gp.ffn
                    x = x + self._ffn(ffn_p, h)
                    continue
                h2 = h.reshape(t, d)
                y, top1, stat = self._serve_moe(moe_layer_idx, gp, h2, valid,
                                                path_ids,
                                                has_state=has_state)
                moe_y = y.reshape(b, s, d)
                if gp.shared is not None:
                    moe_y = moe_y + self._ffn(gp.shared, h)
                x = x + moe_y
                stats.append(stat)
                path_ids = (path_ids * cfg.moe.n_experts + top1) \
                    % self.profile.n_buckets
                moe_layer_idx += 1
            if ks_g:
                ks.append(jnp.stack(ks_g))
                vs.append(jnp.stack(vs_g))
        return x, stats, path_ids, ks, vs

    def _forward(self, tokens, lengths, path_init, *, cache_len: int):
        """Full-sequence forward; captures an LMCache when cache_len > 0."""
        cfg = self.cfg
        tokens = np.asarray(tokens)
        b, s = tokens.shape
        if lengths is None:
            lengths = np.full((b,), s, np.int64)
        lengths = np.asarray(lengths, np.int64)
        x = self._cparams.embed[jnp.asarray(tokens)].astype(
            jnp.dtype(cfg.dtype))
        valid = (np.arange(s)[None, :] < lengths[:, None]).reshape(b * s)
        path_ids = np.zeros((b * s,), np.int64) if path_init is None \
            else np.asarray(path_init, np.int64).reshape(b * s)

        def attn(gp, j, x):
            x, k_j, v_j = self._attn(gp, j, x)
            if not cache_len:
                return x, None, None
            pad = cache_len - s
            if pad:
                k_j = jnp.pad(k_j, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v_j = jnp.pad(v_j, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x, k_j, v_j

        x, stats, path_ids, ks, vs = self._walk_stack(
            x, attn=attn, valid=valid, path_ids=path_ids,
            has_state=False, shape=(b, s))
        x = rms_norm(x, self._cparams.final_norm, cfg.norm_eps)
        last = np.maximum(lengths - 1, 0)
        x_last = np.asarray(x)[np.arange(b), last]
        logits = np.asarray(jnp.asarray(x_last) @ self._w_unembed)
        cache = None
        if cache_len:
            kv = KVCache(jnp.stack(ks), jnp.stack(vs))
            cache = LMCache(kv, None, None, jnp.asarray(lengths, jnp.int32))
        return (np.asarray(logits), stats, path_ids.reshape(b, s), cache)

    def decode_batch(self, tokens, cache: LMCache, path_state,
                     valid=None) -> DecodeResult:
        """One incremental decode step: ONE token per in-flight request.

        tokens:     [B] the most recent token of each request
        cache:      LMCache from prefill_batch / a previous decode_batch
                    (kv: [G, every, B, S_cap, KV, hd]; pos: [B])
        path_state: [B] rolling path-ID state (most recent token's hash)
        valid:      optional [B] bool; False rows are batch padding

        Runs the SAME per-layer two-phase core as prefill — estimate from
        the carried path state, PlanCache with top-2k drift invalidation,
        phase-2 fine-tune on miss, plan-honoring dispatch — in the regime
        the paper's §5 targets: tiny latency-bound batches.  Per-layer
        top-1 choices keep rolling the path state during generation.
        """
        cfg = self.cfg
        tokens = np.asarray(tokens).reshape(-1)
        b = tokens.shape[0]
        if valid is None:
            valid = np.ones((b,), bool)
        valid = np.asarray(valid, bool)
        path_ids = np.asarray(path_state, np.int64).reshape(b).copy()
        x = self._cparams.embed[jnp.asarray(tokens)][:, None].astype(
            jnp.dtype(cfg.dtype))                              # [B, 1, d]
        pos = cache.pos
        group = [0]   # mutable layer-group cursor for the attn closure

        def attn(gp, j, x):
            g = group[0]
            x, k_j, v_j = self._attn_dec(gp, j, x, cache.kv.k[g, j],
                                         cache.kv.v[g, j], pos)
            if j == self.every - 1:
                group[0] += 1
            return x, k_j, v_j

        x, stats, path_ids, ks, vs = self._walk_stack(
            x, attn=attn, valid=valid, path_ids=path_ids,
            has_state=True, shape=(b, 1))
        x = rms_norm(x, self._cparams.final_norm, cfg.norm_eps)
        logits = np.asarray(x[:, 0] @ self._w_unembed)
        new_cache = LMCache(KVCache(jnp.stack(ks), jnp.stack(vs)), None, None,
                            pos + 1)
        return DecodeResult(np.asarray(logits), stats, path_ids, new_cache)


def profile_from_training(cfg: ModelConfig, params, batches,
                          path_len: int = 3, mesh=None) -> PathProfile:
    """Profiling stage (§5.2): replay data through the model, collect
    per-layer top-1 expert choices, accumulate Ψ tables."""
    n_moe = cfg.n_moe_layers
    prof = PathProfile(n_layers=n_moe, n_experts=cfg.moe.n_experts,
                       path_len=path_len)
    fwd = jax.jit(lambda p, b: lm_mod.forward_train(
        mesh, cfg, p, b, lina=False).expert_choices)
    for batch in batches:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        choices = np.asarray(fwd(params, b))       # [n_moe, T]
        prof.profile_batch(choices)
    return prof
