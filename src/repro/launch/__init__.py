"""Launchers: production mesh, dry-run, training and serving drivers."""
from repro.launch.mesh import make_production_mesh, make_mesh, dp_size, ep_size
