"""Jittable train/prefill/decode steps + ShapeDtypeStruct input specs for
every (arch × shape) dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable stand-ins —
no device allocation — exactly what ``jax.jit(...).lower()`` needs.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.serving import PlanArrays
from repro.core.placement import identity_plan
from repro.models import lm as lm_mod
from repro.models.lm import LMCache, LMParams, FRAME_DIM
from repro.optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state
from repro.optim import reduce as reduce_mod

SERVE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.frontend == "audio_stub":
        out["frames"] = _sds((b, s, FRAME_DIM), jnp.bfloat16)
        if shape.kind == "train":
            out["labels"] = _sds((b, s), jnp.int32)
        return out
    if cfg.frontend == "vision_stub":
        st = s - cfg.n_patches
        out["tokens"] = _sds((b, st), jnp.int32)
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            out["labels"] = _sds((b, st), jnp.int32)
        return out
    out["tokens"] = _sds((b, s), jnp.int32)
    if shape.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32)
    return out


def params_struct(cfg: ModelConfig) -> LMParams:
    return jax.eval_shape(partial(lm_mod.init_params, cfg),
                          jax.random.key(0))


def cache_struct(cfg: ModelConfig, shape: ShapeConfig) -> LMCache:
    return jax.eval_shape(partial(lm_mod.init_cache, cfg, shape.global_batch,
                                  shape.seq_len, SERVE_DTYPE))


def opt_struct(cfg: ModelConfig, opt_cfg: AdamWConfig) -> OptState:
    ps = params_struct(cfg)
    return jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), ps)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, opt_cfg=None) -> dict:
    """All step inputs as ShapeDtypeStructs, keyed by step argument name."""
    specs = {"params": params_struct(cfg)}
    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig(state_dtype=cfg.opt_state_dtype)
        specs["opt_state"] = opt_struct(cfg, opt_cfg)
        specs["batch"] = batch_struct(cfg, shape)
    elif shape.kind == "prefill":
        specs["batch"] = batch_struct(cfg, shape)
    else:  # decode / long_decode: one new token against a seq_len cache
        specs["cache"] = cache_struct(cfg, shape)
        specs["token"] = _sds((shape.global_batch,), jnp.int32)
    return specs


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh, opt_cfg: Optional[AdamWConfig] = None,
                    *, lina: bool = True, fsdp: bool = True,
                    dispatch_backend: str = "scatter",
                    microbatches: int = 1,
                    schedule: Optional[str] = None,
                    partition_bytes: float = reduce_mod.DEFAULT_PARTITION_BYTES,
                    grad_compression: Optional[str] = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` scans gradient accumulation over batch slices —
    the standard activation-memory lever (and the granularity at which
    Lina's chunked DP reduction overlaps the next microbatch's compute).

    ``schedule`` selects Lina's §4 gradient-reduction schedule
    (``optim.reduce.SCHEDULES``): the DP-axis reduce becomes an explicit
    chunked psum (``core.microop.prioritized_chunked_reduce``, entered via
    ``optim.reduce.reduce_gradients``'s shard_map) ordered after the
    backward-a2a completion token that
    ``core.moe`` threads out of the shard_map body and ``models.lm``
    carries to the step as ``ModelOutput.a2a_marker``.  ``None`` keeps the
    legacy implicit reduction (whatever XLA's partitioner emits).  With
    ``priority+partition+pipeline`` and ``microbatches > 1`` the chunked
    reduce of each microbatch is interleaved with the next microbatch's
    gradient compute inside an unrolled ``lax.scan``.

    ``grad_compression`` (``"bf16"`` | ``"int8_ef"``) wraps the chunked
    reduce; int8 error feedback is stateful, which changes the signature to
    (params, opt_state, batch, reduce_state) ->
    (params, opt_state, metrics, reduce_state).
    """
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=cfg.opt_state_dtype)
    if grad_compression is not None and schedule is None:
        raise ValueError("grad_compression requires an explicit schedule "
                         f"(one of {reduce_mod.SCHEDULES})")
    rcfg = None
    if schedule is not None:
        rcfg = reduce_mod.ReduceConfig(schedule=schedule,
                                       partition_bytes=partition_bytes,
                                       compression=grad_compression)
    stateful = grad_compression == "int8_ef"
    pipelined = (rcfg is not None and microbatches > 1 and
                 schedule == "priority+partition+pipeline")

    def loss_fn(params, batch):
        out = lm_mod.forward_train(mesh, cfg, params, batch, lina=lina,
                                   dispatch_backend=dispatch_backend,
                                   fsdp=fsdp)
        return out.loss, out

    def explicit_reduce(grads, marker, rstate):
        # order the reduce micro-ops after the backward a2a: expert-weight
        # grad leaves are computed from tokens received over it, and the
        # forward marker pins the forward a2a micro-ops too
        after = reduce_mod.backward_a2a_token(grads, marker)
        return reduce_mod.reduce_gradients(mesh, grads, rcfg,
                                           after=after, state=rstate)

    def grads_of(params, batch, rstate):
        """Returns (grads, loss, aux, rstate) with grads already reduced
        when an explicit schedule is configured."""
        if microbatches <= 1:
            (loss, out), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            if rcfg is not None:
                grads, rstate = explicit_reduce(grads, out.a2a_marker, rstate)
            return grads, loss, out.aux_loss, rstate

        mb = {k: v.reshape(microbatches, v.shape[0] // microbatches,
                           *v.shape[1:]) for k, v in batch.items()}
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        z = jnp.zeros(())

        if pipelined:
            def acc_step(carry, mbatch):
                g_acc, l_acc, a_acc, rs = carry
                (l, out), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                # reduce THIS microbatch's chunks now; unrolled, so XLA's
                # async-collective scheduler overlaps them with the next
                # microbatch's backward compute (psum is linear: per-
                # microbatch mean-reduction sums to the full-batch one)
                g, rs = explicit_reduce(g, out.a2a_marker, rs)
                g_acc = jax.tree.map(lambda a, b_: a + b_, g_acc, g)
                return (g_acc, l_acc + l, a_acc + out.aux_loss, rs), None

            (grads, loss, aux, rstate), _ = jax.lax.scan(
                acc_step, (zeros, z, z, rstate), mb, unroll=microbatches)
        else:
            def acc_step(carry, mbatch):
                g_acc, l_acc, a_acc, m_acc = carry
                (l, out), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                g_acc = jax.tree.map(lambda a, b_: a + b_, g_acc, g)
                return (g_acc, l_acc + l, a_acc + out.aux_loss,
                        m_acc + out.a2a_marker), None

            (grads, loss, aux, marker), _ = jax.lax.scan(
                acc_step, (zeros, z, z, jnp.zeros((), jnp.float32)), mb)
            if rcfg is not None:
                grads, rstate = explicit_reduce(grads, marker, rstate)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return grads, loss / microbatches, aux / microbatches, rstate

    def finish(params, opt_state, grads, loss, aux):
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "aux_loss": aux, **om}
        return params, opt_state, metrics

    if stateful:
        def train_step(params, opt_state, batch, reduce_state):
            grads, loss, aux, reduce_state = grads_of(params, batch,
                                                      reduce_state)
            params, opt_state, metrics = finish(params, opt_state, grads,
                                                loss, aux)
            return params, opt_state, metrics, reduce_state
    else:
        def train_step(params, opt_state, batch):
            grads, loss, aux, _ = grads_of(params, batch, None)
            return finish(params, opt_state, grads, loss, aux)

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh, *, serve_plan=None,
                      serve_top_k=None, fsdp: bool = True):
    def prefill_step(params, batch):
        out = lm_mod.forward_prefill(mesh, cfg, params, batch,
                                     serve_plan=serve_plan,
                                     serve_top_k=serve_top_k, fsdp=fsdp)
        return out.logits
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh, *, serve_plan=None,
                     serve_top_k=None, fsdp: bool = True):
    def decode_step(params, cache, token):
        return lm_mod.decode_step(mesh, cfg, params, cache, token,
                                  serve_plan=serve_plan,
                                  serve_top_k=serve_top_k, fsdp=fsdp)
    return decode_step


def make_serve_plan(cfg: ModelConfig, mesh) -> Optional[PlanArrays]:
    """Identity plan sized to the EP group (popularity plans replace it at
    runtime via the Server)."""
    if not cfg.moe.enabled:
        return None
    from repro.launch.mesh import ep_size
    ep = ep_size(mesh)
    if cfg.moe.n_experts % ep:
        return None
    pack = max(1, cfg.moe.n_experts // ep)
    return PlanArrays.from_plan(
        identity_plan(cfg.moe.n_experts, ep, max_pack=max(pack, 2)))
