"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-moe-smoke \
        --steps 50 --batch 8 --seq 128 [--no-lina] [--ckpt-dir /tmp/ckpt]

Smoke-scale on CPU; on a TPU cluster the same entry point runs the
production mesh (--mesh 16x16) with the dry-run-validated shardings.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.optim.reduce import DEFAULT_PARTITION_BYTES
from repro.runtime import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--no-lina", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--schedule", default="implicit",
                    help="gradient-reduction schedule (optim.reduce."
                         "SCHEDULES); the default 'implicit' keeps XLA's "
                         "own DP reduction (explicit schedules add one "
                         "extra collective per step — use for the "
                         "ablation or with --grad-compression)")
    ap.add_argument("--partition-bytes", type=float,
                    default=DEFAULT_PARTITION_BYTES,
                    help="micro-op size for the partitioned schedules")
    ap.add_argument("--grad-compression", default=None,
                    choices=["bf16", "int8_ef"],
                    help="compress the DP reduce (bf16 cast or int8 with "
                         "error feedback)")
    ap.add_argument("--compute-backend", default=None,
                    choices=["auto", "xla", "pallas"],
                    help="MoE compute backend (MoEConfig.compute_backend): "
                         "Pallas kernels for gating/grouped FFN vs the XLA "
                         "einsum path; default keeps the arch config")
    ap.add_argument("--dispatch-backend", default="scatter",
                    choices=["einsum", "scatter", "pallas"],
                    help="token dispatch/combine backend "
                         "(core.dispatch.BACKENDS)")
    ap.add_argument("--n-microops", type=int, default=None,
                    help="a2a tensor-partition count (MoEConfig.n_microops);"
                         " non-divisors of the capacity resolve to the "
                         "largest valid divisor — the trainer logs the "
                         "requested value per step")
    ap.add_argument("--pipeline-ffn", dest="pipeline_ffn", default=None,
                    action="store_true",
                    help="pipeline expert FFN with a2a micro-ops (Fig. 8b)")
    ap.add_argument("--no-pipeline-ffn", dest="pipeline_ffn",
                    action="store_false",
                    help="baseline: one a2a, full FFN, one a2a")
    ap.add_argument("--shortcut", dest="shortcut", default=None,
                    action="store_true",
                    help="ScMoE shortcut-connected variant: dense branch "
                         "computes under the a2a shadow, summed into the "
                         "combine")
    ap.add_argument("--no-shortcut", dest="shortcut", action="store_false",
                    help="disable the shortcut variant even if the arch "
                         "config enables it")
    ap.add_argument("--mesh", default=None,
                    help="data x model mesh, e.g. 2x4 (needs that many "
                         "devices; on CPU force them with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None,
                    help="write the per-step metrics log (JSON rows)")
    ap.add_argument("--trace-dir", default=None,
                    help="enable span tracing and export the artifact set "
                         "(trace.json Chrome trace for Perfetto, spans.json, "
                         "metrics.prom/.json) into this directory")
    ap.add_argument("--jax-profile-dir", default=None,
                    help="capture a guarded jax.profiler trace window "
                         "(steps 2..5) into this TensorBoard logdir — the "
                         "device-time fwd/bwd split the host spans cannot "
                         "see; degrades to a no-op when capture fails")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.compute_backend is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         compute_backend=args.compute_backend))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1),
                          state_dtype=cfg.opt_state_dtype)
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, lina=not args.no_lina,
                         microbatches=args.microbatches, seed=args.seed,
                         schedule=None if args.schedule == "implicit"
                         else args.schedule,
                         partition_bytes=args.partition_bytes,
                         grad_compression=args.grad_compression,
                         dispatch_backend=args.dispatch_backend,
                         n_microops=args.n_microops,
                         pipeline_ffn=args.pipeline_ffn,
                         shortcut=args.shortcut)
    mesh = None
    if args.mesh:
        from repro.core import axes
        from repro.launch.mesh import make_mesh
        dp_n, ep_n = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((dp_n, ep_n), (axes.DATA, axes.MODEL))
    from repro.obs import ObsContext, StepProfiler
    obs = ObsContext.enabled() if args.trace_dir else ObsContext.disabled()
    trainer = Trainer(cfg, data_cfg, opt_cfg, tcfg, mesh=mesh, obs=obs)
    profiler = StepProfiler(args.jax_profile_dir) \
        if args.jax_profile_dir else None

    def log(step, m):
        if profiler is not None:
            profiler.on_step(step)
        if step % tcfg.log_every == 0:
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"aux {m['aux_loss']:.4f}  gnorm {m['grad_norm']:.3f}",
                  flush=True)

    trainer.run(on_step=log)
    if profiler is not None:
        profiler.close()
        print(f"jax profiler logdir: {args.jax_profile_dir}")
    if trainer.packing_decision:
        print(f"expert packing: {trainer.packing_decision}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(trainer.metrics_log, f)
    if args.trace_dir:
        paths = obs.export(args.trace_dir)
        print(f"trace artifacts: {paths['trace']} (open in "
              f"ui.perfetto.dev), {paths['spans']}, {paths['prom']}")
    first, last = trainer.metrics_log[0]["loss"], trainer.metrics_log[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} over {args.steps} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
