"""Serving driver: profile expert-selection paths, then serve batched
requests with Lina's two-phase popularity scheduling.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-moe-smoke \
        --batches 10 --batch 4 --seq 64 [--policy uniform|lina]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import lm as lm_mod
from repro.runtime.server import MoEServer, ServerConfig, profile_from_training

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--profile-batches", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--path-len", type=int, default=3)
    ap.add_argument("--policy", default="lina", choices=["lina", "uniform"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    assert cfg.moe.enabled, "serve driver targets MoE archs"
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(args.seed))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    ds = SyntheticLM(dcfg)

    print("profiling expert-selection paths ...", flush=True)
    prof = profile_from_training(
        cfg, params, (ds.batch(i) for i in range(args.profile_batches)),
        path_len=args.path_len)

    server = MoEServer(cfg, params, prof,
                       ServerConfig(path_len=args.path_len,
                                    schedule_policy=args.policy))
    ft, acc, loads = [], [], []
    for i in range(args.batches):
        batch = ds.batch(1000 + i)
        logits, stats = server.serve(batch["tokens"])
        ft += [s.finetuned for s in stats]
        acc += [s.est_accurate for s in stats]
        loads += [s.device_load() if callable(getattr(s, 'device_load', None))
                  else s.device_load for s in stats]
        print(f"batch {i}: {len(stats)} MoE layers, "
              f"finetuned {sum(s.finetuned for s in stats)}", flush=True)
    loads = np.stack(loads)
    print(f"policy={args.policy}  fine-tune rate {np.mean(ft):.1%}  "
          f"estimation accuracy {np.mean(acc):.1%}")
    print(f"device load imbalance (max/mean): "
          f"{(loads.max(1) / np.maximum(loads.mean(1), 1e-9)).mean():.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
