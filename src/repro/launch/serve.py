"""Serving driver: profile expert-selection paths, then serve a request
trace through the continuous-batching engine with Lina's two-phase
popularity scheduling (queue -> prefill/decode micro-batches -> plan cache
-> distributed dispatch).  Each request generates ``--max-new-tokens``
tokens through the incremental KV-cache decode path; pass 0 for the
score-only (single-prefill) mode.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-moe-smoke \
        --requests 24 --seq 64 --rate 20 --max-new-tokens 8 \
        [--policy uniform|lina] [--autoscale] [--workload drift] [--warmup]

``--workload`` picks a ``repro.sched.workloads`` scenario (drifting Zipf
topic mixture, flash crowd, diurnal tide, ...) instead of the stationary
Poisson trace; ``--autoscale`` attaches the telemetry-driven controller
(``repro.sched``) so per-layer placement adapts to the traffic between
micro-batches; ``--warmup`` pre-traces the (batch-bucket, min-replicas)
compile grid before the first request arrives.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import lm as lm_mod
from repro.obs import ObsContext
from repro.runtime.engine import (EngineConfig, ServingEngine, simulate,
                                  summarize_results)
from repro.runtime.server import MoEServer, ServerConfig, profile_from_training
from repro.sched import (AdaptiveScheduler, ControllerConfig, SCENARIOS,
                         get_trace)

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=20,
                    help="number of requests in the Poisson trace")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean arrival rate (requests per virtual second)")
    ap.add_argument("--profile-batches", type=int, default=5)
    ap.add_argument("--batch-tokens", type=int, default=256,
                    help="engine micro-batch token budget")
    ap.add_argument("--batch-requests", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=8,
                    help="tokens to generate per request via incremental "
                         "decode (0 = score-only prefill)")
    ap.add_argument("--path-len", type=int, default=3)
    ap.add_argument("--policy", default="lina", choices=["lina", "uniform"])
    ap.add_argument("--compute-backend", default=None,
                    choices=["auto", "xla", "pallas"],
                    help="MoE compute backend for every serve-path layer "
                         "(fused gating + slot dispatch/combine + grouped "
                         "expert FFN on 'pallas'); default keeps the arch "
                         "config")
    ap.add_argument("--no-plan-cache", action="store_true",
                    help="ablation: re-plan every layer of every batch")
    ap.add_argument("--n-microops", type=int, default=None,
                    help="a2a tensor-partition count (MoEConfig.n_microops) "
                         "for the profiling forward passes; non-divisors "
                         "resolve to the largest valid divisor")
    ap.add_argument("--pipeline-ffn", dest="pipeline_ffn", default=None,
                    action="store_true",
                    help="pipeline expert FFN with a2a micro-ops in the "
                         "profiling forward passes")
    ap.add_argument("--no-pipeline-ffn", dest="pipeline_ffn",
                    action="store_false",
                    help="baseline a2a -> FFN -> a2a (no micro-op pipeline)")
    ap.add_argument("--shortcut", dest="shortcut", default=None,
                    action="store_true",
                    help="ScMoE shortcut-connected variant: allocate the "
                         "dense shortcut branch and fuse it under the a2a "
                         "shadow on training-style forwards (serve decode "
                         "adds the same branch outside the plan dispatch)")
    ap.add_argument("--no-shortcut", dest="shortcut", action="store_false",
                    help="disable the shortcut variant even if the arch "
                         "config enables it")
    ap.add_argument("--workload", default=None,
                    choices=sorted(SCENARIOS),
                    help="trace scenario (repro.sched.workloads); default "
                         "is a stationary Poisson trace")
    ap.add_argument("--autoscale", action="store_true",
                    help="attach the telemetry-driven autoscaling "
                         "controller (repro.sched): per-layer plans adapt "
                         "to traffic between micro-batches")
    ap.add_argument("--autoscale-interval", type=int, default=4,
                    help="engine steps between controller evaluations")
    ap.add_argument("--hysteresis", type=float, default=0.1,
                    help="min relative transfer-balance improvement "
                         "before the controller swaps a live plan")
    ap.add_argument("--headroom", type=float, default=0.2,
                    help="drift-rate -> replica-hedge gain")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-trace the (batch-bucket, min-replicas) "
                         "compile grid before serving")
    ap.add_argument("--trace-dir", default=None,
                    help="enable span tracing and export the artifact set "
                         "(trace.json Chrome trace for Perfetto, spans.json, "
                         "metrics.prom/.json) into this directory")
    ap.add_argument("--metrics-out", default=None,
                    help="write a Prometheus-text metrics snapshot here "
                         "(metrics are collected even without --trace-dir)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    assert cfg.moe.enabled, "serve driver targets MoE archs"
    moe_over = {k: v for k, v in (
        ("compute_backend", args.compute_backend),
        ("n_microops", args.n_microops),
        ("pipeline_ffn", args.pipeline_ffn),
        ("shortcut", args.shortcut)) if v is not None}
    if moe_over:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    print(f"moe knobs: n_microops={cfg.moe.n_microops} "
          f"pipeline_ffn={cfg.moe.pipeline_ffn} "
          f"shortcut={cfg.moe.shortcut} "
          f"compute_backend={cfg.moe.compute_backend}", flush=True)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(args.seed))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=4, seed=args.seed)
    ds = SyntheticLM(dcfg)

    print("profiling expert-selection paths ...", flush=True)
    prof = profile_from_training(
        cfg, params, (ds.batch(i) for i in range(args.profile_batches)),
        path_len=args.path_len)

    obs = ObsContext.enabled() if args.trace_dir else ObsContext.disabled()
    server = MoEServer(cfg, params, prof,
                       ServerConfig(path_len=args.path_len,
                                    schedule_policy=args.policy,
                                    plan_cache=not args.no_plan_cache),
                       obs=obs)
    scheduler = None
    if args.autoscale:
        scheduler = AdaptiveScheduler(
            server, ControllerConfig(interval=args.autoscale_interval,
                                     hysteresis=args.hysteresis,
                                     headroom=args.headroom))
    engine = ServingEngine(server,
                           EngineConfig(max_batch_tokens=args.batch_tokens,
                                        max_batch_requests=args.batch_requests),
                           scheduler=scheduler)
    if args.warmup:
        print("warming up (pre-tracing the compile grid) ...", flush=True)
        n = engine.warmup(seqs=(args.seq,),
                          max_new_tokens=args.max_new_tokens)
        print(f"warm-up traced {n} calls", flush=True)

    if args.workload is not None:
        trace = get_trace(args.workload, cfg.vocab_size,
                          n_requests=args.requests, seq=args.seq,
                          rate_hz=args.rate, seed=1000 + args.seed)
        shape = args.workload
    else:
        rng = np.random.RandomState(1000 + args.seed)
        t, trace = 0.0, []
        for _ in range(args.requests):
            t += rng.exponential(1.0 / args.rate)
            trace.append((rng.randint(0, cfg.vocab_size, (args.seq,)), t))
        shape = "stationary-poisson"

    print(f"serving {args.requests} requests ({shape}, rate {args.rate}/s, "
          f"{args.max_new_tokens} new tokens each) ...", flush=True)
    results = simulate(engine, trace, max_new_tokens=args.max_new_tokens)

    m = summarize_results(results)
    stats = engine.layer_stats
    loads = np.stack([s.device_load for s in stats])
    print(f"policy={args.policy}  completed {m['n']} requests")
    print(f"latency p50 {m['latency_p50']*1e3:.1f} ms  "
          f"p95 {m['latency_p95']*1e3:.1f} ms")
    if args.max_new_tokens:
        print(f"TTFT p50 {m['ttft_p50']*1e3:.1f} ms  "
              f"p95 {m['ttft_p95']*1e3:.1f} ms")
        print(f"TPOT p50 {m['tpot_p50']*1e3:.1f} ms  "
              f"p95 {m['tpot_p95']*1e3:.1f} ms  "
              f"({m['gen_tok_s']:.1f} gen tok/s)")
    print(f"plan reuse {engine.plan_reuse_rate:.1%}  "
          f"fine-tune rate {engine.finetune_rate:.1%}  "
          f"estimation accuracy "
          f"{np.mean([s.est_accurate for s in stats]):.1%}")
    print(f"device load imbalance (max/mean): "
          f"{(loads.max(1) / np.maximum(loads.mean(1), 1e-9)).mean():.2f}x")
    if scheduler is not None:
        rep = scheduler.report()
        print(f"autoscaler: {rep['swaps']} swaps (+{rep['bootstraps']} "
              f"bootstraps) over {rep['steps']} steps "
              f"({rep['churn_per_100_steps']:.1f} swaps/100 steps), "
              f"{scheduler.controller.migrated_slots} expert stacks moved")
    if args.trace_dir:
        paths = obs.export(args.trace_dir)
        print(f"trace artifacts: {paths['trace']} (open in "
              f"ui.perfetto.dev), {paths['spans']}, {paths['prom']}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(obs.metrics.to_prometheus())
        print(f"metrics snapshot: {args.metrics_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
