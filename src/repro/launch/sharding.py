"""Sharding rules: PartitionSpec trees for params, optimizer state, batches
and caches — FSDP over (`pod`,`data`), tensor/expert parallel over `model`.

Rules (see DESIGN.md §3):
  column-parallel weights  [..., d, f]  -> P(..., dp, "model")
  row-parallel weights     [..., f, d]  -> P(..., "model", dp)
  experts                  [E, d, f]    -> P("model", None, dp)  (EP + ZeRO-3)
  embeddings               [V, d]       -> P("model", None)      (vocab-sharded)
  SSM/RWKV stacks                       -> FSDP only (no TP; see DESIGN)
Specs are passed through ``safe_spec`` at use so non-divisible dims degrade
to replication instead of erroring (e.g. 56 heads on a 16-way model axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import axes
from repro.models.attention import AttnParams, KVCache
from repro.models.lm import (FFNParams, GroupParams, HybridParams, LMCache,
                             LMParams, RWKVStack)
from repro.models.layers import safe_spec
from repro.optim.adamw import OptState


def _dp(mesh):
    return axes.dp_axes(mesh)


def _tp(mesh):
    return axes.mp_axes(mesh)


def _attn_specs(dp, tp, lead) -> AttnParams:
    n = (None,) * lead
    return AttnParams(
        wq=P(*n, dp, tp), wk=P(*n, dp, tp), wv=P(*n, dp, tp),
        wo=P(*n, tp, dp),
        bq=P(*n, tp), bk=P(*n, tp), bv=P(*n, tp),
        q_norm=P(*n, None), k_norm=P(*n, None),
    )


def _ffn_specs(dp, tp, lead) -> FFNParams:
    n = (None,) * lead
    return FFNParams(w_in=P(*n, dp, tp), w_up=P(*n, dp, tp),
                     w_out=P(*n, tp, dp))


def param_specs(cfg: ModelConfig, mesh, params: LMParams) -> LMParams:
    """Build the PartitionSpec tree mirroring ``params``' structure.

    With ``cfg.tensor_parallel == False`` every mesh axis acts as a data/
    FSDP axis (pure ZeRO-3 — the right regime for sub-1B models where 16-way
    TP only buys collectives; §Perf hillclimb)."""
    if not cfg.tensor_parallel:
        dp = _dp(mesh) + _tp(mesh)
        tp = None
    else:
        dp = _dp(mesh)
        tp = _tp(mesh)

    if isinstance(params.stack, HybridParams):
        mamba_specs = jax.tree.map(lambda a: None, params.stack.mamba)
        mamba_specs = type(params.stack.mamba)(
            in_proj=P(None, dp, None), conv_w=P(None, None, None),
            conv_b=P(None, None), a_log=P(None, None), d_skip=P(None, None),
            dt_bias=P(None, None), norm=P(None, None),
            out_proj=P(None, dp, None))
        stack = HybridParams(
            mamba=mamba_specs, ln_m=P(None, None),
            shared_attn=_attn_specs(dp, tp, 0), shared_ffn=_ffn_specs(dp, tp, 0),
            ln_s1=P(None), ln_s2=P(None))
    elif isinstance(params.stack, RWKVStack):
        blk = type(params.stack.blocks)(
            mu=P(None, None, None), w0=P(None, None),
            w_a=P(None, dp, None), w_b=P(None, None, None),
            wk=P(None, dp, None), wv=P(None, dp, None),
            wr=P(None, dp, None), wg=P(None, dp, None),
            u=P(None, None), wo=P(None, dp, None), ln_x=P(None, None),
            mu_c=P(None, None, None), ck=P(None, dp, None),
            cv=P(None, dp, None), cr=P(None, dp, None))
        stack = RWKVStack(blocks=blk, ln1=P(None, None), ln2=P(None, None))
    else:
        gp = params.stack
        n_dense = gp.ffn is not None
        has_tp = axes.TP in mesh.axis_names
        hid = ((axes.TP,) + dp) if has_tp else dp
        stack = GroupParams(
            attn=_attn_specs(dp, tp, 2),
            ln1=P(None, None, None), ln2=P(None, None, None),
            ffn=_ffn_specs(dp, tp, 2) if n_dense else None,
            moe=type(gp.moe)(
                router=P(None, dp, None),
                wi=P(None, axes.EP_AXIS, None, hid),
                wu=P(None, axes.EP_AXIS, None, hid),
                wo=P(None, axes.EP_AXIS, hid, None),
            ) if gp.moe is not None else None,
            shared=_ffn_specs(dp, tp, 1) if gp.shared is not None else None,
        )

    return LMParams(
        embed=P(tp if tp else dp, None),
        patch_proj=P(None, None) if params.patch_proj is not None else None,
        frame_proj=P(None, None) if params.frame_proj is not None else None,
        mask_emb=P(None) if params.mask_emb is not None else None,
        stack=stack,
        final_norm=P(None),
        lm_head=P(dp, tp) if params.lm_head is not None else None,
    )


def _prune(spec_tree, param_tree):
    """Match spec tree to params (drop specs where params are None)."""
    return jax.tree.map(lambda s, p: s, spec_tree, param_tree)


def shardings_for(mesh, spec_tree, value_tree):
    """Specs -> NamedShardings, degrading non-divisible dims safely."""
    def one(spec, val):
        if val is None:        # spec present but param absent (e.g. no bias)
            return None
        if spec is None:
            spec = P()
        return NamedSharding(mesh, safe_spec(mesh, spec, val.shape))
    return jax.tree.map(one, spec_tree, value_tree,
                        is_leaf=lambda s: isinstance(s, P) or s is None)


def opt_state_specs(param_spec_tree, opt_state: OptState) -> OptState:
    return OptState(step=P(), m=param_spec_tree, v=param_spec_tree)


def serve_uses_fsdp(cfg: ModelConfig, mesh, budget_bytes: float = 10e9) -> bool:
    ep = 1
    for a, s in zip(mesh.axis_names, mesh.devices.shape):
        if a in axes.MP_AXES:
            ep *= s
    return 2.0 * cfg.param_count() / ep > budget_bytes


def serve_param_specs(cfg: ModelConfig, mesh, params: LMParams,
                      budget_bytes: float = 10e9) -> LMParams:
    """Serving shards weights over the model/tp axes ONLY (replicated across
    dp) when the per-device footprint fits — per-step ZeRO re-gathers are a
    training trick, not a serving one.  Falls back to the training (FSDP)
    specs for models too large for TP-only residency (llama4, qwen2-72b)."""
    specs = param_specs(cfg, mesh, params)
    ep = 1
    for a, s in zip(mesh.axis_names, mesh.devices.shape):
        if a in axes.MP_AXES:
            ep *= s
    per_dev = 2.0 * cfg.param_count() / ep  # bf16 serve weights
    if per_dev > budget_bytes:
        return specs
    dp_names = set(axes.DP_AXES)

    def strip(spec):
        if spec is None or not isinstance(spec, P):
            return spec
        out = []
        for e in spec:
            if e is None:
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a not in dp_names)
                out.append(kept if kept else None)
            else:
                out.append(None if e in dp_names else e)
        return P(*out)

    return jax.tree.map(strip, specs,
                        is_leaf=lambda s: isinstance(s, P) or s is None)


def batch_specs(cfg: ModelConfig, mesh, shape: ShapeConfig) -> dict:
    dp = _dp(mesh)
    from repro.launch.mesh import dp_size
    bs = dp if shape.global_batch % dp_size(mesh) == 0 else None
    out = {}
    if cfg.frontend == "audio_stub":
        out["frames"] = P(bs, None, None)
        if shape.kind == "train":
            out["labels"] = P(bs, None)
    else:
        out["tokens"] = P(bs, None)
        if shape.kind == "train":
            out["labels"] = P(bs, None)
        if cfg.frontend == "vision_stub":
            out["patches"] = P(bs, None, None)
    return out


def cache_specs(cfg: ModelConfig, mesh, cache: LMCache) -> LMCache:
    dp = _dp(mesh)
    b = cache.pos.shape[0]
    from repro.launch.mesh import dp_size
    bs = dp if b % dp_size(mesh) == 0 else None

    kv = mamba = rwkv = None
    if cache.kv is not None:
        # KV cache: batch over dp, SEQUENCE over the tp axes (kv-head counts
        # are rarely divisible by 16; a 32k x 128-batch cache at 80 layers is
        # ~1.4TB, so the seq dim must shard — decode attention then runs
        # sequence-parallel with a psum over `model`, which XLA's SPMD
        # partitioner derives from this constraint).
        lead = cache.kv.k.ndim - 4
        kv = KVCache(*(P(*(None,) * lead, bs, _tp(mesh), None, None)
                       for _ in range(2)))
    if cache.mamba is not None:
        mamba = type(cache.mamba)(
            h=P(None, bs, None, None, None), conv=P(None, bs, None, None))
    if cache.rwkv is not None:
        rwkv = type(cache.rwkv)(
            s=P(None, bs, None, None, None), x_tm=P(None, bs, None),
            x_cm=P(None, bs, None))
    return LMCache(kv, mamba, rwkv, P(bs))
