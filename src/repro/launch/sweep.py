"""Dry-run sweep driver: every (arch x applicable shape) x (16x16, 2x16x16).

Each cell runs in a fresh subprocess (jax locks the device count at init and
a crashed cell must not kill the sweep).  Results append to a JSONL file;
existing cells are skipped, so the sweep is resumable.

    PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun/cells.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ASSIGNED, SHAPES, applicable_shapes, skip_reason


def all_cells():
    for cfg in ASSIGNED:
        for shape_name in ("train_4k", "prefill_32k", "decode_32k",
                           "long_500k"):
            for multi_pod in (False, True):
                yield cfg.name, shape_name, multi_pod


def cell_key(arch, shape, multi_pod):
    return f"{arch}|{shape}|{'2x16x16' if multi_pod else '16x16'}"


def load_done(path):
    done = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                done[cell_key(r["arch"], r["shape"],
                              r["mesh"] == "2x16x16")] = r["status"]
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun/cells.jsonl")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only-arch", default=None)
    ap.add_argument("--retry-failed", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = load_done(args.out)
    cells = [c for c in all_cells()
             if args.only_arch in (None, c[0])]
    todo = [c for c in cells
            if cell_key(*c) not in done
            or (args.retry_failed and done[cell_key(*c)] == "error")]
    print(f"{len(cells)} cells, {len(cells) - len(todo)} done, "
          f"{len(todo)} to run", flush=True)

    for i, (arch, shape, mp) in enumerate(todo):
        from repro.configs import get_config
        cfg = get_config(arch)
        sr = skip_reason(cfg, SHAPES[shape])
        t0 = time.time()
        if sr:
            with open(args.out, "a") as f:
                f.write(json.dumps({
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "skip", "reason": sr}) + "\n")
            print(f"[{i+1}/{len(todo)}] SKIP {arch} x {shape}: {sr}",
                  flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--json", args.out]
        if mp:
            cmd.append("--multi-pod")
        print(f"[{i+1}/{len(todo)}] RUN {arch} x {shape} "
              f"{'2x16x16' if mp else '16x16'} ...", flush=True)
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            dt = time.time() - t0
            if p.returncode != 0:
                err = (p.stderr or "")[-2000:]
                with open(args.out, "a") as f:
                    f.write(json.dumps({
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error", "error": err}) + "\n")
                print(f"   ERROR ({dt:.0f}s): {err.splitlines()[-1] if err else '?'}",
                      flush=True)
            else:
                print(f"   ok ({dt:.0f}s)", flush=True)
        except subprocess.TimeoutExpired:
            with open(args.out, "a") as f:
                f.write(json.dumps({
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error", "error": "timeout"}) + "\n")
            print("   TIMEOUT", flush=True)


if __name__ == "__main__":
    main()
