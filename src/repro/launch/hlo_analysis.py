"""Compiled-HLO analysis for the dry-run roofline.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count (loop-blind), so collective traffic inside scan-over-layers would be
undercounted ~n_layers-fold.  This module parses the compiled module text,
builds the computation call graph, extracts each while loop's trip count
from its condition computation (the loop-bound constant), and multiplies
every collective's bytes by the product of enclosing trip counts.

Byte conventions (per device, 'wire bytes' on a ring):
    all-reduce          2 * size * (n-1)/n
    all-gather          out_size * (n-1)/n      (each device receives the rest)
    reduce-scatter      in_size  * (n-1)/n
    all-to-all          size * (n-1)/n
    collective-permute  size
``size`` is the op's result byte size parsed from the result type (tuples
summed); n is the replica-group size.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_TYPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_OP_NAME_RE = re.compile(
    r"\b(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute|"
    r"while)\(")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_GROUPS_RE = re.compile(r"replica_groups=(?:\[(\d+),(\d+)\]|\{([^}]*)\})")
_CALL_ATTR_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _type_bytes(type_str: str) -> int:
    return sum((lambda n: n)(
        _DTYPE_BYTES[d] * eval("*".join(s.split(",")) if s else "1"))
        for d, s in _TYPE_RE.findall(type_str))


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_RE.search(rest)
    if not m:
        return default
    if m.group(2) is not None:
        return int(m.group(2))       # iota [n_groups, group_size]
    groups = m.group(3).split("},{")  # explicit {{0,1},{2,3}}
    first = groups[0].strip("{}")
    return max(1, len(first.split(",")))


def parse_module(hlo: str) -> dict:
    """Split into computations; collect per-computation collectives/whiles."""
    comps: Dict[str, dict] = {}
    cur = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line.strip()) if ("->" in line and "{" in line) \
            else None
        if mc:
            cur = mc.group(1)
            comps[cur] = {"collectives": [], "whiles": [], "constants": [],
                          "calls": []}
            continue
        if cur is None:
            continue
        for c in _CONST_RE.findall(line):
            comps[cur]["constants"].append(int(c))
        ma = _ASSIGN_RE.match(line)
        mo = _OP_NAME_RE.search(line) if ma else None
        if not mo:
            # conditional/call computations execute once per visit
            if "conditional(" in line or re.search(r"\bcall\(", line):
                for ref in re.findall(
                        r"(?:true_computation|false_computation|to_apply)="
                        r"%?([\w.\-]+)", line):
                    comps[cur]["calls"].append(ref)
                mb = re.search(r"branch_computations=\{([^}]*)\}", line)
                if mb:
                    comps[cur]["calls"].extend(
                        x.strip().lstrip("%") for x in mb.group(1).split(","))
            continue
        op = mo.group(1).replace("-start", "")
        rest = line[mo.end():]
        if op == "while":
            attrs = dict(re.findall(r"(body|condition)=%?([\w.\-]+)", rest))
            mt = re.search(r'known_trip_count..:..n.:.(\d+)', rest)
            if mt:
                attrs["trip"] = int(mt.group(1))
            comps[cur]["whiles"].append(attrs)
        else:
            # result type = text between '=' and the op name
            type_str = line[ma.end():mo.start()]
            size = _type_bytes(type_str)
            n = _group_size(rest, 1)
            comps[cur]["collectives"].append((op, size, n))
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    cs = comps.get(cond_name, {}).get("constants", [])
    return max(cs) if cs else 1


def wire_bytes(op: str, size: int, n: int) -> float:
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if op == "all-reduce":
        return 2.0 * size * f
    if op == "all-gather":
        return size * f                  # size = gathered result
    if op == "reduce-scatter":
        return size * n * f              # size = scattered result; input n*size
    if op == "all-to-all":
        return size * f
    return float(size)                   # collective-permute


def collective_summary(hlo: str, entry: str = None) -> dict:
    comps = parse_module(hlo)
    if entry is None:
        # entry computation: the one never referenced as body/cond... use the
        # one containing top-level whiles + most collectives; XLA names it
        # like the jit'd function. Fall back: computation named 'main' or
        # containing '.entry' else the largest.
        referenced = set()
        for c in comps.values():
            for w in c["whiles"]:
                referenced.update(w.values())
        cands = [k for k in comps if k not in referenced]
        entry = None
        for k in cands:
            if "main" in k or "entry" in k:
                entry = k
                break
        if entry is None and cands:
            entry = max(cands, key=lambda k: len(comps[k]["collectives"])
                        + len(comps[k]["whiles"]))

    totals = defaultdict(float)
    raw = defaultdict(float)
    counts = defaultdict(float)
    seen = set()

    def visit(name: str, mult: float):
        if name not in comps or (name, mult) in seen:
            return
        seen.add((name, mult))
        c = comps[name]
        for op, size, n in c["collectives"]:
            totals[op] += wire_bytes(op, size, n) * mult
            raw[op] += size * mult
            counts[op] += mult
        for w in c["whiles"]:
            trip = w.get("trip") or _trip_count(comps, w.get("condition", ""))
            if "body" in w:
                visit(w["body"], mult * trip)
        for ref in c["calls"]:
            visit(ref, mult)

    if entry:
        visit(entry, 1.0)
    return {
        "entry": entry,
        "wire_bytes": dict(totals),
        "raw_bytes": dict(raw),
        "counts": {k: int(v) for k, v in counts.items()},
        "total_wire_bytes": float(sum(totals.values())),
        "total_raw_bytes": float(sum(raw.values())),
    }


def while_trip_counts(hlo: str) -> List[int]:
    comps = parse_module(hlo)
    out = []
    for c in comps.values():
        for w in c["whiles"]:
            out.append(w.get("trip")
                       or _trip_count(comps, w.get("condition", "")))
    return out
