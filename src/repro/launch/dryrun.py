import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder devices; print memory/cost analysis; extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--json results/dryrun/...json]

The two lines above MUST stay the first statements in this module (jax locks
the device count on first init).
"""
import argparse
import json
import re
import sys
import time

import jax
import numpy as np

from repro.configs import (REGISTRY, SHAPES, V5E, applicable_shapes,
                           get_config, skip_reason)
from repro.core import axes as ax
from repro.launch.mesh import (make_production_mesh, arch_mesh, dp_size,
                               ep_size, mesh_context)
from repro.launch.sharding import (batch_specs, cache_specs, opt_state_specs,
                                   param_specs, serve_param_specs,
                                   shardings_for)
from repro.launch.steps import (input_specs, make_decode_step,
                                make_prefill_step, make_serve_plan,
                                make_train_step)

from repro.launch.analytic import analytic_cost
from repro.launch.hlo_analysis import collective_summary


def roofline_terms(flops_global: float, bytes_global: float,
                   coll_bytes_per_dev: float, n_chips: int, hw=V5E) -> dict:
    """The three terms (seconds): compute/memory terms from the analytic
    model (global / chips); collective term from the trip-count-corrected
    per-device HLO wire bytes (the HLO module is the per-device SPMD
    program, so its collective bytes are already per-chip)."""
    return {
        "compute_s": flops_global / (n_chips * hw.peak_flops),
        "memory_s": bytes_global / (n_chips * hw.hbm_bw),
        "collective_s": coll_bytes_per_dev / (hw.ici_links * hw.ici_bw),
        "collective_s_single_link": coll_bytes_per_dev / hw.ici_bw,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, lina: bool = True,
             seq_parallel: bool = True, microbatches: int = 1,
             cache_batch_only: bool = False, dp_only: bool = False,
             kv_split: bool = False, tag: str = "",
             verbose: bool = True) -> dict:
    import dataclasses
    cfg = dataclasses.replace(get_config(arch), seq_parallel=seq_parallel,
                              tensor_parallel=not dp_only)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip", "reason": reason}

    # the physical production mesh, re-viewed with an expert/tp split when
    # the arch's expert count does not divide the 16-way model axis
    mesh = arch_mesh(cfg, multi_pod=multi_pod)
    if kv_split and cfg.n_kv_heads and 16 % cfg.n_kv_heads == 0:
        # decode hillclimb: split `model` into (kv-heads x seq) so the KV
        # cache shards fully AND the per-step cache update stays local
        import jax.sharding as jsh
        from repro.launch.mesh import axis_types_kwargs
        kvh = cfg.n_kv_heads
        shp = ((2, 16, kvh, 16 // kvh) if multi_pod
               else (16, kvh, 16 // kvh))
        names = ax.MESH_AXES if multi_pod else ax.MESH_AXES[1:]
        mesh = jsh.Mesh(mesh.devices.reshape(shp), names,
                        **axis_types_kwargs(len(names)))
    n_chips = mesh.size
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        pspec = param_specs(cfg, mesh, specs["params"])
    else:
        pspec = serve_param_specs(cfg, mesh, specs["params"])
    p_shard = shardings_for(mesh, pspec, specs["params"])

    t0 = time.time()
    with mesh_context(mesh):
        if shape.kind == "train":
            step = make_train_step(cfg, mesh, lina=lina, fsdp=True,
                                   microbatches=microbatches)
            o_shard = shardings_for(mesh, opt_state_specs(pspec,
                                                          specs["opt_state"]),
                                    specs["opt_state"])
            b_shard = shardings_for(mesh, batch_specs(cfg, mesh, shape),
                                    specs["batch"])
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(specs["params"], specs["opt_state"],
                                   specs["batch"])
        elif shape.kind == "prefill":
            from repro.launch.sharding import serve_uses_fsdp
            plan = make_serve_plan(cfg, mesh)
            step = make_prefill_step(cfg, mesh, serve_plan=plan,
                                     fsdp=serve_uses_fsdp(cfg, mesh))
            b_shard = shardings_for(mesh, batch_specs(cfg, mesh, shape),
                                    specs["batch"])
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:
            from repro.launch.sharding import serve_uses_fsdp
            plan = make_serve_plan(cfg, mesh)
            step = make_decode_step(cfg, mesh, serve_plan=plan,
                                    fsdp=serve_uses_fsdp(cfg, mesh))
            cspec = cache_specs(cfg, mesh, specs["cache"])
            if kv_split and cspec.kv is not None:
                from repro.models.attention import KVCache
                from jax.sharding import PartitionSpec as P
                lead = specs["cache"].kv.k.ndim - 4
                dpx = ax.DP_AXES if multi_pod else (ax.DATA,)
                # [.., B->dp, S->tp, KV->model, hd]
                kv = KVCache(*(P(*(None,) * lead, dpx, ax.TP, ax.MODEL, None)
                               for _ in range(2)))
                cspec = cspec._replace(kv=kv)
            if cache_batch_only and cspec.kv is not None:
                # hillclimb variant: KV cache sharded on batch only (no
                # sequence sharding over `model`)
                from repro.models.attention import KVCache
                from jax.sharding import PartitionSpec as P
                lead = specs["cache"].kv.k.ndim - 4
                dpx = ax.DP_AXES if multi_pod else (ax.DATA,)
                kv = KVCache(*(P(*(None,) * lead, dpx, None, None, None)
                               for _ in range(2)))
                cspec = cspec._replace(kv=kv)
            c_shard = shardings_for(mesh, cspec, specs["cache"])
            from jax.sharding import NamedSharding, PartitionSpec as P
            tok_spec = P(ax.DP_AXES if multi_pod else (ax.DATA,)) \
                if shape.global_batch % dp_size(mesh) == 0 else P(None)
            t_shard = NamedSharding(mesh, tok_spec)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, c_shard, t_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(specs["params"], specs["cache"],
                                   specs["token"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax<=0.4.x returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_summary(hlo)
    ana = analytic_cost(cfg, shape)

    hlo_flops_dev = float(cost.get("flops", 0.0))       # loop-blind; reference
    hlo_bytes_dev = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(ana.flops_global, ana.hbm_bytes_global,
                           coll["total_wire_bytes"], n_chips)

    # MODEL_FLOPS per spec: 6ND (train) / 2ND (inference), N = active params
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill") else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * cfg.active_param_count() * tokens

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "status": "ok", "lina": lina,
        "seq_parallel": seq_parallel, "microbatches": microbatches,
        "tag": tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "analytic_flops_global": ana.flops_global,
        "analytic_hbm_bytes_global": ana.hbm_bytes_global,
        "hlo_flops_per_device_loopblind": hlo_flops_dev,
        "hlo_bytes_per_device_loopblind": hlo_bytes_dev,
        "collectives": coll,
        "memory_analysis": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes_estimate": int(mem.argument_size_in_bytes
                                       + mem.temp_size_in_bytes),
        },
        "roofline": terms,
        "model_flops_global": float(model_flops),
        "useful_flops_ratio": float(model_flops / max(ana.flops_global, 1)),
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    result["dominant_term"] = dom
    result["roofline_fraction"] = terms["compute_s"] / max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    if verbose:
        print(f"== {arch} x {shape_name} on {result['mesh']} "
              f"({n_chips} chips) lina={lina} ==")
        print(f"memory_analysis: {result['memory_analysis']}")
        print(f"analytic: flops={ana.flops_global:.3e} "
              f"hbm={ana.hbm_bytes_global:.3e} ({ana.notes})")
        print(f"hlo(loop-blind ref): flops/dev={hlo_flops_dev:.3e} "
              f"bytes/dev={hlo_bytes_dev:.3e}")
        print(f"collectives(trip-corrected): {coll['counts']} -> "
              f"{coll['total_wire_bytes']/1e9:.3f} GB wire/dev")
        print(f"roofline: compute={terms['compute_s']*1e3:.2f}ms "
              f"memory={terms['memory_s']*1e3:.2f}ms "
              f"collective={terms['collective_s']*1e3:.2f}ms "
              f"dominant={dom} useful_ratio={result['useful_flops_ratio']:.2f} "
              f"fraction={result['roofline_fraction']:.3f}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-lina", action="store_true",
                    help="baseline schedule (single a2a, no micro-ops)")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence parallelism (paper-baseline mode)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cache-batch-only", action="store_true",
                    help="decode: shard KV cache on batch only")
    ap.add_argument("--dp-only", action="store_true",
                    help="no tensor parallelism: all axes FSDP/data")
    ap.add_argument("--kv-split", action="store_true",
                    help="decode: split model axis into (kv-heads x seq)")
    ap.add_argument("--tag", default="", help="label for §Perf iterations")
    ap.add_argument("--json", default=None, help="append result to this file")
    args = ap.parse_args(argv)

    res = run_cell(args.arch, args.shape, args.multi_pod,
                   lina=not args.no_lina, seq_parallel=not args.no_sp,
                   microbatches=args.microbatches,
                   cache_batch_only=args.cache_batch_only,
                   dp_only=args.dp_only, kv_split=args.kv_split,
                   tag=args.tag)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "a") as f:
            f.write(json.dumps(res) + "\n")
    return 0 if res["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())
