"""Analytic FLOP / HBM-traffic model per (arch x shape).

Why analytic: XLA's ``cost_analysis`` on a compiled module counts while-loop
bodies once (loop-blind), so a scan-over-layers program under-reports FLOPs
~n_layers-fold.  We therefore compute the roofline's compute and memory
terms from the architecture's exact math (the MaxText-MFU approach), and use
the compiled HLO for (a) the collective inventory with trip-count-corrected
bytes (launch/hlo_analysis.py) and (b) the peak-memory fit check.  A fully
unrolled compile of a small arch calibrates this model against true HLO
counts (see EXPERIMENTS.md §Roofline methodology).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class AnalyticCost:
    flops_global: float          # FLOPs for one step
    hbm_bytes_global: float      # HBM traffic for one step
    matmul_params: float         # params participating in matmuls (active)
    notes: str = ""


def _matmul_params_active(cfg: ModelConfig) -> float:
    """Active matmul params per token (excludes embedding lookup, includes
    the unembedding projection)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim
    ffn_mult = 3 if cfg.ffn_type == "swiglu" else 2
    attn = 2 * (cfg.n_heads * hd * d) + 2 * (cfg.n_kv_heads * hd * d)
    total = v * d  # unembed
    if cfg.layer_pattern:
        pat = cfg.layer_pattern
        n_m = len(pat)
        d_in = d * cfg.ssm.expand
        n = cfg.ssm.d_state
        per_mamba = d * (2 * d_in + 2 * n + d_in // cfg.ssm.head_dim) + d_in * d
        total += n_m * per_mamba
        total += pat.count("*") * (attn + ffn_mult * d * f)
    elif cfg.attention_free:
        total += cfg.n_layers * (5 * d * d + d * 64 + 3 * d * f)
    else:
        total += cfg.n_layers * attn
        n_moe = cfg.n_moe_layers
        n_dense = cfg.n_layers - n_moe
        total += n_dense * ffn_mult * d * f
        if cfg.moe.enabled:
            e_f = cfg.moe.d_ff or f
            per_exp = ffn_mult * d * e_f
            total += n_moe * (cfg.moe.top_k + (1 if cfg.moe.shared_expert
                                               else 0)) * per_exp
            total += n_moe * d * cfg.moe.n_experts  # router
    return float(total)


def _attention_flops(cfg: ModelConfig, b: int, s_q: int, s_kv: int,
                     fwd_mult: float) -> float:
    """QK^T + PV flops; causal halves the effective context."""
    if cfg.attention_free:
        return 0.0
    if cfg.layer_pattern:
        n_attn = sum(ch in "A*" for ch in cfg.layer_pattern)
    else:
        n_attn = cfg.n_layers
    eff_kv = s_kv
    if cfg.sliding_window:
        eff_kv = min(s_kv, cfg.sliding_window)
    elif cfg.causal and s_q == s_kv:
        eff_kv = s_kv / 2
    d_attn = cfg.n_heads * cfg.resolved_head_dim
    return fwd_mult * 2.0 * 2.0 * b * s_q * eff_kv * d_attn * n_attn


def _ssm_scan_flops(cfg: ModelConfig, tokens: float, fwd_mult: float) -> float:
    """Chunked-scan state math (intra-chunk matmuls + state updates)."""
    if cfg.layer_pattern:           # mamba2
        d_in = cfg.d_model * cfg.ssm.expand
        n = cfg.ssm.d_state
        q = cfg.ssm.chunk
        # per token: intra M@X ~ 2*q*d_in, CB ~ 2*q*n, state update ~ 4*d_in*n
        per_tok = 2 * q * d_in + 2 * q * n + 4 * d_in * n
        return fwd_mult * per_tok * tokens * len(cfg.layer_pattern)
    if cfg.attention_free:          # rwkv6
        hd = cfg.ssm.head_dim
        per_tok = 4 * cfg.d_model * hd   # S update + readout per head
        return fwd_mult * per_tok * tokens * cfg.n_layers
    return 0.0


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig) -> AnalyticCost:
    b, s = shape.global_batch, shape.seq_len
    n_mm = _matmul_params_active(cfg)
    p_total = cfg.param_count()
    act_bytes = 2  # bf16 activations
    d = cfg.d_model

    if shape.kind == "train":
        tokens = float(b) * s
        flops = 6.0 * n_mm * tokens
        flops += _attention_flops(cfg, b, s, s, fwd_mult=3.0)
        flops += _ssm_scan_flops(cfg, tokens, 3.0)
        # HBM: weights fwd + bwd reads (compute dtype) + grad write +
        # optimizer (read p,m,v + write p,m,v in state dtype) + remat
        # activation traffic (write carry, read back, recompute ~2x reads)
        w_c = 2 * p_total * act_bytes
        opt_b = {"float32": 4, "bfloat16": 2}[cfg.opt_state_dtype]
        opt = p_total * (2 * 4 + 4 * opt_b)  # master rw + m,v rw
        acts = 4.0 * cfg.n_layers * tokens * d * act_bytes
        hbm = w_c + opt + acts
        note = "6ND + 12BS^2 attn; remat act traffic 4LTd"
    elif shape.kind == "prefill":
        tokens = float(b) * s
        flops = 2.0 * n_mm * tokens
        flops += _attention_flops(cfg, b, s, s, fwd_mult=1.0)
        flops += _ssm_scan_flops(cfg, tokens, 1.0)
        hbm = p_total * act_bytes + 2.0 * cfg.n_layers * tokens * d * act_bytes
        note = "2ND fwd"
    else:  # decode / long_decode: one token, seq_len-deep cache
        tokens = float(b)
        flops = 2.0 * n_mm * tokens
        flops += _attention_flops(cfg, b, 1, s, fwd_mult=1.0)
        flops += _ssm_scan_flops(cfg, tokens, 1.0)
        # decode is weight+cache bound: all weights read once per step,
        # full KV cache (or SSM state) read once
        if cfg.attention_free or cfg.layer_pattern:
            d_in = d * max(cfg.ssm.expand, 1)
            state = cfg.n_layers * b * d_in * cfg.ssm.d_state * 4
            if cfg.attention_free:
                state = cfg.n_layers * b * d * cfg.ssm.head_dim * 4
            cache_bytes = 2 * state
        else:
            eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
            n_attn = cfg.n_layers
            cache_bytes = (2 * n_attn * b * eff * cfg.n_kv_heads
                           * cfg.resolved_head_dim * 2)
        hbm = p_total * act_bytes + cache_bytes
        note = "2ND + cache read"
    return AnalyticCost(flops, hbm, n_mm, note)
