"""Production meshes.  Functions, not module constants, so importing never
touches jax device state (the dry-run must set XLA_FLAGS first)."""
from __future__ import annotations

import jax

from repro.core import axes


def mesh_context(mesh):
    """Activate ``mesh`` as the ambient mesh, across JAX versions.

    Newer JAX spells this ``jax.set_mesh`` (or ``jax.sharding.use_mesh``);
    the pinned 0.4.x only offers ``Mesh.__enter__``.  All three return a
    context manager, so callers write ``with mesh_context(mesh):``.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # Mesh is itself a context manager


def axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where the JAX version has AxisType; {} on
    the pinned 0.4.x (whose meshes are implicitly fully auto)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {} if at is None else {"axis_types": (at.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    names = (axes.POD, axes.DATA, axes.MODEL) if multi_pod \
        else (axes.DATA, axes.MODEL)
    return jax.make_mesh(shape, names, **axis_types_kwargs(len(names)))


def make_mesh(shape, axes):
    """Arbitrary (test-sized) mesh with the same axis conventions."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **axis_types_kwargs(len(axes)))


def dp_size(mesh) -> int:
    sizes = axes.axis_sizes(mesh)
    return sizes.get(axes.POD, 1) * sizes.get(axes.DATA, 1)


def ep_size(mesh) -> int:
    return axes.axis_sizes(mesh).get(axes.MODEL, 1)


def tp_axes(mesh):
    """The tensor-parallel axes: `model` plus the expert-slicing `tp` axis
    when present (archs whose expert count < 16)."""
    return axes.mp_axes(mesh)


def arch_mesh(cfg, *, multi_pod: bool = False):
    """The production mesh, re-viewed for the arch: when n_experts does not
    divide the 16-way model axis, split it into (model=ep, tp=16/ep) so the
    MoE a2a runs over `model` and experts are tensor-sliced over `tp`
    (DeepSpeed-MoE expert slicing).  Device order is preserved — this is the
    same physical 16x16 (or 2x16x16) mesh required by the dry-run."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    e = getattr(cfg.moe, "n_experts", 0)
    if not e or 16 % e != 0 or e >= 16:
        return mesh
    ep, tp = e, 16 // e
    shape = (2, 16, ep, tp) if multi_pod else (16, ep, tp)
    names = axes.MESH_AXES if multi_pod else axes.MESH_AXES[1:]
    import jax.sharding as jsh
    return jsh.Mesh(mesh.devices.reshape(shape), names,
                    **axis_types_kwargs(len(names)))
