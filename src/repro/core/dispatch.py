"""Token dispatch/combine into per-expert capacity buffers.

Three backends with identical semantics:
  * ``einsum``  — one-hot matmul (GShard reference; O(T*E*C) FLOPs). Oracle.
  * ``scatter`` — index-based scatter/gather (production; O(T) memory
    traffic, but pays a [T*k, d] broadcast copy of the token block on the
    way in).
  * ``pallas``  — fused kernels (``kernels/dispatch.py`` via
    ``kernels.ops.dispatch_combine_op``): a metadata-sized int32 slot
    inversion plus one single-pass gather kernel per direction — no
    [T, E, C] one-hot, no broadcast copy.  Differentiable (linear-map
    custom VJPs), so it is selectable for training from ``TrainerConfig``.

All produce ``[E, C, d]`` dispatch buffers that the expert-parallel a2a
(``core/microop.py``) exchanges across the `model` mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gating import GatingResult
from repro.kernels.dispatch import invert_slots
from repro.kernels import ops as kernel_ops


# ---------------------------------------------------------------------------
# einsum backend (oracle)
# ---------------------------------------------------------------------------

def dispatch_mask(g: GatingResult, n_experts: int, cap: int) -> jax.Array:
    """[T, k] metadata -> boolean mask [T, E, C]."""
    e_oh = jax.nn.one_hot(g.expert_idx, n_experts, dtype=jnp.float32)
    c_oh = jax.nn.one_hot(g.position, cap, dtype=jnp.float32)
    keep = (~g.dropped).astype(jnp.float32)[..., None, None]
    return jnp.einsum("tke,tkc->tec", e_oh * keep[..., 0], c_oh * keep[..., 0])


def dispatch_einsum(x: jax.Array, g: GatingResult, n_experts: int,
                    cap: int) -> jax.Array:
    """x: [T, d] -> buffers [E, C, d]."""
    mask = dispatch_mask(g, n_experts, cap)
    return jnp.einsum("tec,td->ecd", mask, x.astype(jnp.float32)).astype(x.dtype)


def combine_einsum(buf: jax.Array, g: GatingResult, n_experts: int,
                   cap: int) -> jax.Array:
    """buffers [E, C, d] -> [T, d], weighted by gate weights."""
    e_oh = jax.nn.one_hot(g.expert_idx, n_experts, dtype=jnp.float32)
    c_oh = jax.nn.one_hot(g.position, cap, dtype=jnp.float32)
    w = g.gate_weights.astype(jnp.float32)
    cmb = jnp.einsum("tke,tkc,tk->tec", e_oh, c_oh, w)
    return jnp.einsum("tec,ecd->td", cmb, buf.astype(jnp.float32)).astype(buf.dtype)


# ---------------------------------------------------------------------------
# scatter backend (production)
# ---------------------------------------------------------------------------

def dispatch_scatter(x: jax.Array, g: GatingResult, n_experts: int,
                     cap: int) -> jax.Array:
    """x: [T, d] -> buffers [E, C, d] via scatter; dropped tokens discarded."""
    t, d = x.shape
    k = g.expert_idx.shape[1]
    flat_slot = g.expert_idx * cap + g.position                    # [T, k]
    # route dropped tokens to a scratch row appended at the end
    flat_slot = jnp.where(g.dropped, n_experts * cap, flat_slot)
    buf = jnp.zeros((n_experts * cap + 1, d), x.dtype)
    src = jnp.broadcast_to(x[:, None, :], (t, k, d)).reshape(t * k, d)
    buf = buf.at[flat_slot.reshape(-1)].set(src, mode="drop")
    return buf[:-1].reshape(n_experts, cap, d)


def combine_scatter(buf: jax.Array, g: GatingResult, n_experts: int,
                    cap: int) -> jax.Array:
    flat = buf.reshape(n_experts * cap, -1)
    slot = g.expert_idx * cap + g.position                         # [T, k]
    slot = jnp.clip(slot, 0, n_experts * cap - 1)
    gathered = flat[slot]                                          # [T, k, d]
    w = jnp.where(g.dropped, 0.0, g.gate_weights)[..., None]
    # combine in the buffer dtype: keeps the BACKWARD a2a cotangents bf16
    # (an f32 upcast here doubles the dominant collective's wire bytes)
    return jnp.sum(gathered * w.astype(buf.dtype), axis=1)


# ---------------------------------------------------------------------------
# pallas backend (fused kernels)
# ---------------------------------------------------------------------------

def _flat_rows(g: GatingResult, cap: int) -> jax.Array:
    """[T, k] flat capacity-buffer row per (token, choice); -1 = dropped."""
    return jnp.where(g.dropped, -1, g.expert_idx * cap + g.position)


def dispatch_pallas(x: jax.Array, g: GatingResult, n_experts: int,
                    cap: int) -> jax.Array:
    """x: [T, d] -> buffers [E, C, d] via the fused gather kernel."""
    rows = _flat_rows(g, cap)
    src_tok, _ = invert_slots(rows, n_experts * cap)
    disp, _ = kernel_ops.dispatch_combine_op(use_pallas=True)
    return disp(x, src_tok, rows).reshape(n_experts, cap, x.shape[-1])


def combine_pallas(buf: jax.Array, g: GatingResult, n_experts: int,
                   cap: int) -> jax.Array:
    rows = _flat_rows(g, cap)
    w = jnp.where(g.dropped, 0.0, g.gate_weights)
    _, comb = kernel_ops.dispatch_combine_op(use_pallas=True)
    return comb(buf.reshape(n_experts * cap, -1), rows, w)


BACKENDS = {
    "einsum": (dispatch_einsum, combine_einsum),
    "scatter": (dispatch_scatter, combine_scatter),
    "pallas": (dispatch_pallas, combine_pallas),
}


def get_backend(name: str):
    return BACKENDS[name]
