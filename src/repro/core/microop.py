"""Lina §4: tensor partitioning into micro-ops, a2a<->FFN pipelining, and
prioritized gradient synchronization — re-expressed for TPU/XLA.

GPU Lina uses a runtime priority queue over NCCL micro-ops.  Under SPMD the
whole step schedule is static, so priority becomes *program order with
explicit dependency edges*:

  * ``chunked_all_to_all``   — partitions the dispatch buffer along the
    capacity dim into ``n_chunks`` independent ``lax.all_to_all`` micro-ops.
  * ``pipelined_expert_ffn`` — interleaves chunk k's expert FFN with chunk
    k+1's a2a (unrolled, so XLA's async collective scheduler overlaps the
    collective-start/done pair with the matmuls). This reproduces Fig. 8b.
  * ``prioritized_chunked_reduce`` — partitions the DP gradient reduction
    into uniform micro-ops and *orders every one of them after* a given
    token (the completion marker of the backward a2a), so the gradient
    allreduce can never contend with all-to-all — Lina's priority rule,
    enforced at compile time rather than at runtime.  This is strictly
    stronger than the paper's best case (Fig. 7d assumes known arrival
    times; SPMD gives us exactly that).

All functions are shape-polymorphic and run inside ``shard_map``.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

Axis = str | Sequence[str]


def _token_of(x) -> jax.Array:
    """A tiny data-dependent marker used to build dependency edges."""
    leaf = jax.tree_util.tree_leaves(x)[0]
    return jnp.real(leaf).reshape(-1)[0].astype(jnp.float32) * 0.0


@jax.custom_jvp
def _barrier_flat(flat: tuple, token: jax.Array) -> tuple:
    out = lax.optimization_barrier(tuple(flat) + (token,))
    return tuple(out[:-1])


@_barrier_flat.defjvp
def _barrier_flat_jvp(primals, tangents):
    # ``optimization_barrier`` has no autodiff rule; the barrier is a
    # scheduling edge, not math, so tangents pass straight through (the
    # backward pass gets its own ordering from optim/reduce.py).
    flat, token = primals
    tflat, _ = tangents
    return _barrier_flat(flat, token), tuple(tflat)


def ordered_after(x, token: jax.Array):
    """Return ``x`` with a compile-time dependency on ``token``.

    ``optimization_barrier`` pins program order: XLA may still overlap the
    downstream collective with *compute*, but cannot hoist it before the
    barrier input — i.e. before the a2a it must yield to.  Differentiable
    (pass-through tangents), so it is safe inside the forward pass.
    """
    flat, treedef = jax.tree_util.tree_flatten(x)
    out = _barrier_flat(tuple(flat), token)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# a2a micro-ops (forward path)
# ---------------------------------------------------------------------------

def all_to_all_ec(buf: jax.Array, axis: Axis) -> jax.Array:
    """Expert-parallel exchange: local [E, C, d] -> [E_local*ep, C, d] where
    the leading dim becomes (src_shard, local_expert) after the exchange.

    With ep shards on ``axis`` and E = ep * E_local experts, shard i sends
    rows [j*E_local:(j+1)*E_local] to shard j and receives the rows destined
    to its own experts from everyone: a textbook MoE dispatch a2a.
    """
    ep = lax.psum(1, axis)
    e, c, d = buf.shape
    assert e % ep == 0, f"experts {e} not divisible by ep {ep}"
    x = buf.reshape(ep, e // ep, c, d)
    x = lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
    # [ep, E_local, C, d] with axis0 = source shard
    return x.reshape(ep * (e // ep), c, d)


def all_to_all_ec_inverse(buf: jax.Array, axis: Axis, n_experts: int) -> jax.Array:
    """Inverse exchange: [ep*E_local, C, d] -> [E, C, d] back at the source."""
    ep = lax.psum(1, axis)
    ec, c, d = buf.shape
    x = buf.reshape(ep, ec // ep, c, d)
    x = lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
    return x.reshape(n_experts, c, d)


def resolve_chunk_count(capacity: int, n_chunks: int) -> int:
    """Largest divisor of ``capacity`` that is ≤ ``n_chunks``.

    The paper's micro-ops are uniform, so the capacity dim must split
    evenly.  A requested count that does not divide C is resolved — not
    silently decremented inside the a2a — to the largest valid divisor;
    callers surface the *chosen* count (benchmark rows record both).
    """
    capacity = int(capacity)
    n = max(1, min(int(n_chunks), capacity))
    while capacity % n:
        n -= 1
    return n


def chunked_all_to_all(buf: jax.Array, axis: Axis, n_chunks: int,
                       inverse: bool = False, n_experts: int = 0) -> list:
    """Partition [E, C, d] along C into a2a micro-ops.

    Returns the list of exchanged chunks (callers pipeline compute between
    them); ``len()`` of the result is the *chosen* chunk count, resolved by
    :func:`resolve_chunk_count`.  Equal-size partitioning mirrors the
    paper's uniform micro-ops.
    """
    n_chunks = resolve_chunk_count(buf.shape[1], n_chunks)
    pieces = jnp.split(buf, n_chunks, axis=1)
    fn = (lambda p: all_to_all_ec_inverse(p, axis, n_experts)) if inverse \
        else (lambda p: all_to_all_ec(p, axis))
    return [fn(p) for p in pieces]


def pipelined_expert_ffn(buf: jax.Array, expert_fn: Callable, axis: Axis,
                         n_chunks: int, n_experts: int,
                         pipeline: bool = True) -> tuple:
    """Fig. 8b as a double-buffered software pipeline: chunk k's expert FFN
    overlaps chunk k+1's dispatch a2a, and chunk k's combine (return) a2a is
    interleaved behind chunk k+1's dispatch a2a in the collective stream.

    buf:        local dispatch buffers [E, C, d] (E = global expert count).
    expert_fn:  [E_recv, n_tok, d] -> [E_recv, n_tok, d] — the local experts
                applied to received tokens (E_recv = ep * E_local rows whose
                expert identity is row % E_local... resolved by caller).
    Returns (combined local buffers [E, C, d], a2a_done_token).

    Scheduling model (mirrors ``prioritized_chunked_reduce``): collectives
    are chained through ``ordered_after`` tokens so they serialize among
    themselves in issue order — one virtual comm stream — while each chunk's
    FFN carries *no* ordering edge to the next dispatch and therefore fills
    the gap under the in-flight a2a.  Per iteration the issue order is

        dispatch-a2a(k+1)  →  expert_fn(k)  →  combine-a2a(k)

    so the grouped FFN of chunk k runs in the shadow of chunk k+1's
    dispatch, and chunk k's return a2a slots in right behind it.

    With ``pipeline=False`` this is the baseline: one a2a, full FFN, one a2a
    (the DeepSpeed schedule of Fig. 2).
    """
    if not pipeline:
        n_chunks = 1
    n_chunks = resolve_chunk_count(buf.shape[1], n_chunks)
    pieces = jnp.split(buf, n_chunks, axis=1)

    # prologue: fill the pipeline with chunk 0's dispatch a2a.
    recv = all_to_all_ec(pieces[0], axis)
    comm_tok = _token_of(recv)
    back = []
    for k in range(n_chunks):
        if k + 1 < n_chunks:
            # issue chunk k+1's dispatch a2a on the comm stream *before*
            # chunk k's FFN appears in program order; the FFN below has no
            # edge to it, so XLA overlaps the two (paper §4.2).
            nxt = ordered_after(pieces[k + 1], comm_tok)
            recv_next = all_to_all_ec(nxt, axis)
            comm_tok = _token_of(recv_next)
        else:
            recv_next = None
        # each received chunk: [ep*E_local, C/n, d]; FFN is token-granular so
        # it starts as soon as the chunk lands — re-entrant grouped_ffn call
        # under the pallas backend, one kernel launch per landed chunk.
        out_k = expert_fn(recv)
        # chunk k's return a2a joins the comm stream behind chunk k+1's
        # dispatch: interleaved, never ahead of it.
        ret = all_to_all_ec_inverse(ordered_after(out_k, comm_tok), axis,
                                    n_experts)
        comm_tok = _token_of(ret)
        back.append(ret)
        recv = recv_next
    combined = jnp.concatenate(back, axis=1) if len(back) > 1 else back[0]
    return combined, _token_of(combined)


# ---------------------------------------------------------------------------
# prioritized gradient reduction (backward path)
# ---------------------------------------------------------------------------

def flatten_tree(tree) -> tuple:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,))
    return flat, (treedef, shapes, sizes)


def unflatten_tree(flat: jax.Array, spec) -> object:
    treedef, shapes, sizes = spec
    leaves, off = [], 0
    for shp, sz in zip(shapes, sizes):
        leaves.append(flat[off:off + sz].reshape(shp))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, leaves)


def prioritized_chunked_reduce(grads, axis: Axis, n_chunks: int,
                               after: jax.Array | None = None,
                               mean: bool = True):
    """DP gradient reduction as uniform psum micro-ops, each ordered after
    ``after`` (the backward a2a completion marker).  Equal-size chunks over
    the flattened gradient vector = the paper's tensor partitioning (no
    gradient-boundary bucketing, §4.2).
    """
    flat, spec = flatten_tree(grads)
    n = flat.size
    if n == 0:
        return grads
    n_chunks = max(1, min(n_chunks, n))
    pad = (-n) % n_chunks
    flat = jnp.pad(flat, (0, pad))
    pieces = jnp.split(flat, n_chunks)
    denom = lax.psum(1, axis) if mean else 1
    out = []
    for p in pieces:
        if after is not None:
            p = ordered_after(p, after)
        r = lax.psum(p, axis)
        out.append(r / denom if mean else r)
        # chain: the next micro-op is ordered after this one completes, so
        # micro-ops serialize among themselves (single 'virtual stream') and
        # leave gaps only where compute appears between them.
        after = _token_of(r)
    red = jnp.concatenate(out)[:n]
    return unflatten_tree(red, spec)
