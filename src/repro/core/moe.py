"""The expert-parallel MoE layer: gating -> dispatch -> (micro-op a2a
pipelined with expert FFN) -> combine, under ``shard_map`` on the `model`
mesh axis, with optional Lina inference placement (replication/packing).

This is the module a user drops in place of an FFN (paper Fig. 1).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.core import axes
from repro.core import dispatch as D
from repro.core import microop
from repro.core.axes import DP_AXES, EP_AXIS
from repro.core.gating import capacity, router_top_k_gating
from repro.kernels.ops import grouped_ffn_op, resolve_backend

_DEFAULT_MESH = None


def default_mesh():
    """1-device ('data','model') mesh so the shard_map body (and its
    collectives) also runs on a bare CPU — used by smoke tests."""
    global _DEFAULT_MESH
    if _DEFAULT_MESH is None:
        _DEFAULT_MESH = jax.make_mesh((1, 1), (axes.DATA, axes.MODEL))
    return _DEFAULT_MESH


class MoEParams(NamedTuple):
    router: jax.Array        # [d, E]
    wi: jax.Array            # [E, d, f]   (gate proj for swiglu)
    wu: jax.Array | None     # [E, d, f]   (up proj; None for gelu FFN)
    wo: jax.Array            # [E, f, d]


class MoEOutput(NamedTuple):
    y: jax.Array             # [T, d]
    aux_loss: jax.Array      # scalar
    expert_idx: jax.Array    # [T, k] — for popularity profiling/estimation
    router_probs: jax.Array  # [T, E]
    a2a_token: jax.Array     # zero scalar data-dependent on the layer's a2a
    #                          micro-ops — the ordering signal Lina's
    #                          prioritized gradient reduce yields to
    #                          (optim/reduce.py); threaded, never dropped


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int,
                    ffn_type: str = "swiglu", dtype=jnp.float32) -> MoEParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    router = (jax.random.normal(k1, (d_model, n_experts)) * s_in).astype(dtype)
    wi = (jax.random.normal(k2, (n_experts, d_model, d_ff)) * s_in).astype(dtype)
    wu = (jax.random.normal(k3, (n_experts, d_model, d_ff)) * s_in).astype(dtype) \
        if ffn_type == "swiglu" else None
    wo = (jax.random.normal(k4, (n_experts, d_ff, d_model)) * s_out).astype(dtype)
    return MoEParams(router, wi, wu, wo)


def expert_ffn(wi, wu, wo, x, ffn_type: str = "swiglu",
               compute_backend: str = "xla"):
    """x: [E_rows, n, d] with per-row expert weights [E_rows, d, f].

    ``compute_backend="pallas"`` runs the grouped-GEMM kernel
    (``kernels.ops.grouped_ffn_op``, custom-VJP so the train step's
    backward stays on tiled grouped GEMMs); ``"xla"`` keeps the einsum
    formulation the kernel is oracle-tested against.
    """
    if compute_backend == "pallas":
        return grouped_ffn_op(x, wi, wu, wo, ffn_type, use_pallas=True)
    h = jnp.einsum("end,edf->enf", x, wi)
    if ffn_type == "swiglu":
        u = jnp.einsum("end,edf->enf", x, wu)
        h = jax.nn.silu(h) * u
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("enf,efd->end", h, wo)


# ---------------------------------------------------------------------------
# The shard_map body: everything below runs per-device with explicit
# collectives — this is where Lina's schedule lives.
# ---------------------------------------------------------------------------

def _moe_shard_body(x, router, wi, wu, wo, *, cfg: MoEConfig, ffn_type: str,
                    dispatch_backend: str, ep_axis: str, dp_axes,
                    lina: bool, fsdp: bool = False, tp_axis: str | None = None,
                    top_k: int | None = None, shortcut=None):
    """x: [T_local, d].  Expert weights arrive expert-sharded over ep_axis:
    wi/wu/wo have leading dim E_local = E / ep.  With ``fsdp`` they are
    additionally sharded over the dp axes on the hidden dim and gathered
    here, per layer, so the resident footprint stays 1/(ep*dp) of the stack
    (ZeRO-3 for experts; the per-layer gather overlaps with gating).  With
    ``tp_axis`` the expert hidden dim stays sharded (expert slicing) and the
    output projection carries a psum over tp."""
    if fsdp:
        wi = lax.all_gather(wi, dp_axes, axis=2, tiled=True)
        if wu is not None:
            wu = lax.all_gather(wu, dp_axes, axis=2, tiled=True)
        wo = lax.all_gather(wo, dp_axes, axis=1, tiled=True)
    b_loc, s_loc, d_model = x.shape
    x = x.reshape(b_loc * s_loc, d_model)      # local flatten: no resharding
    t_local = x.shape[0]
    e = cfg.n_experts
    k = top_k or cfg.top_k
    cap = capacity(t_local, e, k, cfg.capacity_factor)

    backend = resolve_backend(cfg.compute_backend)
    # fused router matmul + softmax + top-k on the pallas backend
    g = router_top_k_gating(x, router, k, cap, cfg.aux_loss_weight,
                            compute_backend=backend)

    disp, comb = D.get_backend(dispatch_backend)
    buf = disp(x, g, e, cap)                                      # [E, C, d]

    ep = lax.psum(1, ep_axis)
    e_local = e // ep

    def ffn_rows(rows):                                           # [ep*E_local, c, d]
        rs = rows.reshape(ep, e_local, rows.shape[1], d_model)
        rs = rs.transpose(1, 0, 2, 3).reshape(e_local, ep * rows.shape[1], d_model)
        out = expert_ffn(wi, wu, wo, rs, ffn_type, backend)
        if tp_axis is not None:
            out = lax.psum(out, tp_axis)     # contract the tp-sharded hidden
        out = out.reshape(e_local, ep, rows.shape[1], d_model)
        return out.transpose(1, 0, 2, 3).reshape(ep * e_local, rows.shape[1], d_model)

    sc_out = None
    if shortcut is not None:
        # ScMoE shortcut branch: dense FFN on the *local* tokens with
        # replicated weights.  Ordered after the dispatch buffer so it sits
        # between dispatch and combine in program order — under the a2a
        # shadow — but carries no edge into the collective chain itself, so
        # the a2a micro-ops never wait on it.
        sw_in, sw_up, sw_out = shortcut
        xs = microop.ordered_after(x, microop._token_of(buf))
        hs = xs @ sw_in
        if ffn_type == "swiglu":
            hs = jax.nn.silu(hs) * (xs @ sw_up)
        else:
            hs = jax.nn.gelu(hs)
        sc_out = hs @ sw_out

    n_chunks = cfg.n_microops if lina else 1
    out_buf, a2a_token = microop.pipelined_expert_ffn(
        buf, ffn_rows, ep_axis, n_chunks, e, pipeline=lina and cfg.pipeline_ffn)

    y = comb(out_buf, g, e, cap)                                  # [T, d]
    if sc_out is not None:
        y = y + sc_out                     # summed into the combine (ScMoE)
    y = y.reshape(b_loc, s_loc, d_model)
    return y, g.aux_loss, g.expert_idx, g.router_probs, a2a_token


def moe_layer(mesh, x, params: MoEParams, cfg: MoEConfig, *,
              ffn_type: str = "swiglu", dispatch_backend: str = "scatter",
              lina: bool = True, fsdp: bool = False,
              top_k: int | None = None, shortcut_params=None) -> MoEOutput:
    """x: [B, S, d].  Experts sharded over `model`; tokens sharded batch-over
    dp and sequence-over-`model` — the SAME layout sequence parallelism uses
    between blocks, so entering the MoE region costs no resharding, and each
    device gates/dispatches only its T/(dp*ep) tokens (replicated over `tp`,
    whose ranks must see identical tokens for the expert-slicing psum).
    With ``fsdp``, expert hidden dims are additionally sharded over dp; a
    `tp` mesh axis tensor-slices the expert hidden dim (expert slicing)."""
    if mesh is None:
        mesh = default_mesh()
    tp = axes.TP if axes.TP in mesh.axis_names else None
    dp = axes.dp_axes(mesh)
    sizes = axes.axis_sizes(mesh)
    b_, s_, _ = x.shape
    dp_n = 1
    for a in dp:
        dp_n *= sizes.get(a, 1)
    bq = dp if b_ % dp_n == 0 else None
    sq = EP_AXIS if s_ % sizes.get(EP_AXIS, 1) == 0 else None
    bspec = P(bq, sq, None)
    hid = ((tp,) if tp else ()) + (dp if fsdp else ())  # hidden-dim shards
    if hid:
        wspec_i = P(EP_AXIS, None, hid)   # [E->ep, d, f->tp(+dp)]
        wspec_o = P(EP_AXIS, hid, None)   # [E->ep, f->tp(+dp), d]
    else:
        wspec_i = wspec_o = P(EP_AXIS, None, None)
    body = partial(_moe_shard_body, cfg=cfg, ffn_type=ffn_type,
                   dispatch_backend=dispatch_backend, ep_axis=EP_AXIS,
                   dp_axes=dp, lina=lina, fsdp=fsdp, tp_axis=tp, top_k=top_k)
    has_wu = params.wu is not None
    wu_spec = wspec_i if has_wu else P()
    wu = params.wu if has_wu else jnp.zeros((), x.dtype)

    # ScMoE shortcut weights ride along replicated (dense branch, no ep/tp
    # sharding); dummy scalars when the variant is off.
    has_sc = shortcut_params is not None
    if has_sc:
        sc_wi, sc_wu, sc_wo = shortcut_params
    else:
        sc_wi = sc_wu = sc_wo = None
    has_sc_wu = has_sc and sc_wu is not None
    dummy = jnp.zeros((), x.dtype)
    sc_in = (sc_wi if has_sc else dummy, sc_wu if has_sc_wu else dummy,
             sc_wo if has_sc else dummy)
    sc_specs = (P(None, None) if has_sc else P(),
                P(None, None) if has_sc_wu else P(),
                P(None, None) if has_sc else P())

    aux_axes = (dp if bq else ()) + ((EP_AXIS,) if sq else ())

    def wrapped(x, router, wi, wu, wo, sc_wi, sc_wu, sc_wo):
        wu_ = wu if has_wu else None
        sc = (sc_wi, sc_wu if has_sc_wu else None, sc_wo) if has_sc else None
        y, aux, eidx, probs, tok = body(x, router, wi, wu_, wo, shortcut=sc)
        # aux loss: tokens differ across every sharded axis -> mean over them
        if aux_axes:
            aux = lax.pmean(aux, aux_axes)
        return y, aux, eidx, probs, tok

    # token-flat outputs (expert ids / probs) keep the (b, s)-derived shard
    flat_axes = (tuple(bq) if bq else ()) + ((sq,) if sq else ())
    flat_spec = P(flat_axes or None, None)
    y, aux, eidx, probs, tok = shard_map(
        wrapped, mesh=mesh,
        in_specs=(bspec, P(None, None), wspec_i, wu_spec, wspec_o) + sc_specs,
        out_specs=(bspec, P(), flat_spec, flat_spec, P()),
        check_rep=False,
    )(x, params.router, params.wi, wu, params.wo, *sc_in)
    return MoEOutput(y, aux, eidx, probs, tok)
