"""Lina §5 two-phase resource scheduling: Eq. 1 device counts, replication of
popular experts, first-fit-decreasing packing of unpopular ones, and the
phase-2 fine-tune check.

The planner runs on the host (numpy; it is the 'scheduler on device 0' of
§6.2) and emits static-shape plan arrays that the jitted serve step consumes:

  slot_expert  [n_devices, S]  expert hosted in each device sub-slot (-1 free)
  replica_of   [E, R]          device-slot index of each replica of e (-1 pad)
  n_replicas   [E]             live replica count per expert

Token routing: a token choosing expert e goes to replica (pos mod
n_replicas[e]) — balancing the a2a transfer size across the replicas' links,
which is exactly the paper's 'coordinate all-to-all correspondingly'.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.popularity import top2k_sets_match


@dataclass(frozen=True)
class PlacementPlan:
    slot_expert: np.ndarray    # [n_devices, S] int32
    replica_of: np.ndarray     # [E, R] int32 (flat slot ids; -1 pad)
    n_replicas: np.ndarray     # [E] int32
    popularity: np.ndarray     # [E] float32 — the estimate the plan used

    @property
    def n_devices(self) -> int:
        return self.slot_expert.shape[0]

    @property
    def max_pack(self) -> int:
        return self.slot_expert.shape[1]

    def device_load(self, popularity: Optional[np.ndarray] = None
                    ) -> np.ndarray:
        """Token share per device under this plan.  By default evaluated
        against the popularity the plan was built from; pass the *actual*
        popularity to score the plan against the realized workload."""
        pop = self.popularity if popularity is None else \
            np.asarray(popularity, np.float64)
        load = np.zeros((self.n_devices,), np.float64)
        share = pop / np.maximum(self.n_replicas, 1)
        for d in range(self.n_devices):
            for s in range(self.max_pack):
                ex = self.slot_expert[d, s]
                if ex >= 0:
                    load[d] += share[ex]
        return load


def identity_plan(n_experts: int, n_devices: int, max_pack: int = 4,
                  max_replicas: int = 0) -> PlacementPlan:
    """Uniform baseline: expert e on device e*D//E (DeepSpeed layout)."""
    r = max_replicas or max_pack
    slot = np.full((n_devices, max_pack), -1, np.int32)
    rep = np.full((n_experts, r), -1, np.int32)
    per_dev = -(-n_experts // n_devices)          # ceil: experts per device
    assert per_dev <= max_pack, "identity layout exceeds max_pack"
    for e in range(n_experts):
        d, s = divmod(e, per_dev)
        slot[d, s] = e
        rep[e, 0] = d * max_pack + s
    pop = np.full((n_experts,), 1.0 / n_experts, np.float32)
    return PlacementPlan(slot, rep, np.ones((n_experts,), np.int32), pop)


def _poison_dead_bins(bin_load: np.ndarray, bin_count: np.ndarray,
                      dead_devices, max_pack: int) -> int:
    """Mark dead devices as full/infinitely loaded so every placement loop
    skips them without special-casing; returns the live device count."""
    dead = sorted(int(d) for d in (dead_devices or ()))
    for d in dead:
        if 0 <= d < bin_count.shape[0]:
            bin_count[d] = max_pack
            bin_load[d] = np.inf
    return bin_count.shape[0] - len(dead)


def plan_placement(popularity: np.ndarray, n_devices: int, max_pack: int = 4,
                   max_replicas: int = 0,
                   dead_devices=frozenset()) -> PlacementPlan:
    """Phase-1 planner (Eq. 1 + FFD).

    n_e = N * pop_e devices for expert e; experts with n_e >= 1 are
    *replicated* on round(n_e) devices; the fractional rest are packed
    first-fit-decreasing (item size = n_e, bin capacity = 1 device-worth of
    throughput, at most ``max_pack`` experts per device §6.2); experts not in
    any top-k list (pop 0) go to remaining free slots, else randomly.

    ``dead_devices`` masks failed devices out of the placement entirely
    (degradation path): no expert is placed on them, and the replica budget
    shrinks to the surviving slots.
    """
    e = popularity.shape[0]
    pop = np.asarray(popularity, np.float64)
    pop = pop / max(pop.sum(), 1e-12)
    max_replicas = max_replicas or max_pack

    slot_expert = np.full((n_devices, max_pack), -1, np.int32)
    bin_load = np.zeros((n_devices,), np.float64)
    bin_count = np.zeros((n_devices,), np.int32)
    live = _poison_dead_bins(bin_load, bin_count, dead_devices, max_pack)
    # over-subscription (e > live slots) keeps the legacy behavior: the
    # replica budget goes negative and the coldest experts are shed to
    # zero replicas (weighted_route drops their tokens on the -1 slot id)
    n_e = pop * live
    replicas: List[List[int]] = [[] for _ in range(e)]

    def place(ex: int, load: float) -> None:
        # first-fit over devices ordered by current load, respecting the
        # load cap when possible
        order = np.lexsort((np.arange(n_devices), bin_load))
        for d in order:
            if bin_count[d] < max_pack and (bin_load[d] + load <= 1.0 + 1e-9
                                            or bin_count[d] == 0):
                break
        else:
            # every bin is load-full: take the least-loaded device with a
            # free sub-slot regardless of cap (paper's 'randomly assigned')
            for d in order:
                if bin_count[d] < max_pack:
                    break
            else:
                raise ValueError("placement overflow: no free sub-slot")
        slot_expert[d, bin_count[d]] = ex
        replicas[ex].append(int(d * max_pack + bin_count[d]))
        bin_load[d] += load
        bin_count[d] += 1

    # 1) popular experts first, replicated proportionally (FFD = decreasing);
    # replica budget reserves one sub-slot per expert so nobody is orphaned.
    replica_budget = live * max_pack - e
    order = np.argsort(-n_e)
    for ex in order:
        ex = int(ex)
        r = int(min(max(1, round(n_e[ex])), max_replicas, live,
                    1 + replica_budget))
        replica_budget -= r - 1
        for _ in range(r):
            place(ex, n_e[ex] / r)

    rep = np.full((e, max_replicas), -1, np.int32)
    n_rep = np.zeros((e,), np.int32)
    for ex in range(e):
        rs = replicas[ex][:max_replicas]
        n_rep[ex] = len(rs)
        rep[ex, : len(rs)] = rs
    return PlacementPlan(slot_expert, rep, n_rep, pop.astype(np.float32))


def shed_to_budget(replica_counts: np.ndarray, popularity: np.ndarray,
                   budget: int) -> np.ndarray:
    """Shrink replica counts to a total slot budget: always decrement a
    least-popular expert among the widest.  The single shedding policy
    shared by ``plan_from_replicas`` and the controller's
    ``replica_targets`` — it preserves popularity-monotonicity of the
    counts, which the controller's tests pin."""
    r = np.asarray(replica_counts, np.int64).copy()
    pop = np.asarray(popularity, np.float64)
    if budget < r.shape[0]:
        raise ValueError(f"slot budget {budget} cannot host every one of "
                         f"{r.shape[0]} experts once")
    while r.sum() > budget:
        mx = r.max()
        cand = np.flatnonzero(r == mx)
        r[cand[np.argmin(pop[cand])]] -= 1
    return r


def plan_from_replicas(popularity: np.ndarray, replica_counts: np.ndarray,
                       n_devices: int, max_pack: int = 4,
                       rep_width: int = 0,
                       prev: Optional[PlacementPlan] = None,
                       dead_devices=frozenset()) -> PlacementPlan:
    """Build a plan honoring *explicit* per-expert replica counts — the
    constructor the adaptive controller (``repro.sched.controller``) uses,
    where Eq. 1's ``round(N * pop_e)`` is replaced by telemetry-driven
    targets (EWMA popularity + drift headroom).

    Each expert e gets exactly ``replica_counts[e]`` slots (clipped to
    [1, n_devices] and, collectively, to the ``n_devices * max_pack`` slot
    budget — largest counts shed first).  Replicas are placed greedily on
    the least-loaded device that (a) has a free sub-slot and (b) does not
    already host e (falling back to any free sub-slot when every device
    hosts it), so one expert's replicas spread across links — the §5
    transfer-balance objective.

    ``prev`` makes the placement *incremental*: up to the new count, an
    expert keeps the devices that already host it, so a swap only moves
    the weights of genuinely added replicas (minimizing the §6.2 weight
    swap the controller's migration model charges for).

    ``rep_width`` fixes the replica-table width (default ``n_devices``) so
    controller-emitted plans keep a static shape across swaps and never
    force a dispatch recompile.

    ``dead_devices`` (degradation path) removes failed devices from the
    placement: retained-from-``prev`` replicas on dead devices are dropped,
    nothing new lands there, and both the per-expert clip and the slot
    budget shrink to the surviving devices.
    """
    pop = np.asarray(popularity, np.float64)
    pop = pop / max(pop.sum(), 1e-12)
    e = pop.shape[0]
    dead = {int(d) for d in (dead_devices or ()) if 0 <= d < n_devices}
    live = n_devices - len(dead)
    r = np.clip(np.asarray(replica_counts, np.int64), 1, max(live, 1))
    budget = live * max_pack
    if budget < e:
        raise ValueError(f"{live} live devices x {max_pack} slots cannot "
                         f"host {e} experts")
    r = shed_to_budget(r, pop, budget)
    rep_width = rep_width or n_devices

    keep: List[List[int]] = [[] for _ in range(e)]
    if prev is not None and prev.n_devices == n_devices:
        for d in range(n_devices):
            if d in dead:
                continue
            for ex in prev.slot_expert[d]:
                ex = int(ex)
                if ex >= 0 and len(keep[ex]) < int(r[ex]) \
                        and d not in keep[ex]:
                    keep[ex].append(d)

    slot_expert = np.full((n_devices, max_pack), -1, np.int32)
    bin_load = np.zeros((n_devices,), np.float64)
    bin_count = np.zeros((n_devices,), np.int32)
    _poison_dead_bins(bin_load, bin_count, dead, max_pack)
    replicas: List[List[int]] = [[] for _ in range(e)]

    def assign(ex: int, d: int, share: float) -> None:
        slot_expert[d, bin_count[d]] = ex
        replicas[ex].append(int(d * max_pack + bin_count[d]))
        bin_load[d] += share
        bin_count[d] += 1

    for ex in np.argsort(-pop):                 # heaviest experts first
        ex = int(ex)
        share = pop[ex] / r[ex]
        retained = [d for d in keep[ex] if bin_count[d] < max_pack]
        for d in retained:
            assign(ex, d, share)
        for _ in range(int(r[ex]) - len(retained)):
            order = np.lexsort((np.arange(n_devices), bin_load))
            hosting = {s // max_pack for s in replicas[ex]}
            free = [d for d in order if bin_count[d] < max_pack]
            if not free:
                raise ValueError("placement overflow: no free sub-slot")
            spread = [d for d in free if d not in hosting]
            assign(ex, (spread or free)[0], share)

    rep = np.full((e, rep_width), -1, np.int32)
    n_rep = np.zeros((e,), np.int32)
    for ex in range(e):
        rs = replicas[ex][:rep_width]
        n_rep[ex] = len(rs)
        rep[ex, : len(rs)] = rs
    return PlacementPlan(slot_expert, rep, n_rep, pop.astype(np.float32))


def route_weights(plan: PlacementPlan, rounds: int = 6) -> np.ndarray:
    """Per-(expert, replica) routing fractions that balance modeled
    per-DEVICE token load under the plan's popularity — the starting point
    of the §5 weighted zero-migration split (``serving.PlanArrays``).

    Round-robin gives every replica of an expert 1/r of its tokens, so a
    replica that shares its device with other hot experts still eats the
    straggler.  A few rounds of iterative proportional fitting fix that:
    start uniform over live replicas, compute each device's modeled load
    (sum over hosted replicas of weight * expert popularity), and divide
    every replica's weight by its device's relative load, renormalizing
    per expert.  Rows sum to 1 over live replicas; pad/dead columns are 0.
    """
    ro = np.asarray(plan.replica_of, np.int64)
    nr = np.asarray(plan.n_replicas, np.int64)
    e, r_w = ro.shape
    pop = np.asarray(plan.popularity, np.float64)
    pop = pop / max(pop.sum(), 1e-12)
    live = (np.arange(r_w)[None, :] < np.clip(nr, 1, r_w)[:, None]) \
        & (ro >= 0)
    dev = np.clip(ro, 0, None) // max(plan.max_pack, 1)          # [E, R]
    n_live = np.maximum(live.sum(1, keepdims=True), 1)
    w = np.where(live, 1.0 / n_live, 0.0)
    for _ in range(max(0, int(rounds))):
        load = np.zeros(plan.n_devices, np.float64)
        np.add.at(load, dev[live], (w * pop[:, None])[live])
        rel = load / max(load.mean(), 1e-12)
        w = np.where(live, w / np.maximum(rel[dev], 1e-6), 0.0)
        w = w / np.maximum(w.sum(1, keepdims=True), 1e-12)
    return w.astype(np.float32)


def transfer_balance_cost(plan: PlacementPlan,
                          popularity: np.ndarray) -> float:
    """The §5 objective the controller minimizes: the *maximum* per-device
    token share under ``popularity`` — proportional to the largest
    all-to-all transfer any link carries (the layer's straggler)."""
    return float(plan.device_load(np.asarray(popularity, np.float64)).max())


def migration_slots(old: PlacementPlan, new: PlacementPlan) -> int:
    """Weight-movement cost of swapping ``old`` for ``new``: the number of
    (device, expert) placements present in the new plan but not the old —
    each one is an expert weight stack some device must fetch (§6.2's
    weight swap)."""
    moved = 0
    for d in range(new.n_devices):
        old_hosted = set(int(x) for x in old.slot_expert[d] if x >= 0) \
            if d < old.n_devices else set()
        for ex in new.slot_expert[d]:
            if ex >= 0 and int(ex) not in old_hosted:
                moved += 1
    return moved


def needs_finetune(est_pop: np.ndarray, actual_pop: np.ndarray,
                   top_k: int) -> bool:
    """Phase 2 (§5.2): fine-tune iff top-2k estimated != top-2k actual.
    Delegates to the canonical check in ``core.popularity``."""
    return not top2k_sets_match(est_pop, actual_pop, top_k)


def two_phase_plan(est_pop: np.ndarray, actual_pop: Optional[np.ndarray],
                   n_devices: int, top_k: int, max_pack: int = 4):
    """Returns (plan, finetuned: bool).  Phase 1 always plans from the
    estimate; phase 2 re-plans from the actual popularity on deviation."""
    plan = plan_placement(est_pop, n_devices, max_pack)
    if actual_pop is not None and needs_finetune(est_pop, actual_pop, top_k):
        return plan_placement(actual_pop, n_devices, max_pack), True
    return plan, False


@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0      # misses caused by popularity drift
    device_invalidations: int = 0   # entries dropped by a device failure

    @property
    def reuse_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclass
class PlanCache:
    """Per-MoE-layer PlacementPlan cache for the serving engine.

    Phase-1 planning amortizes across batches: a layer's cached plan is
    reused while the top-2k set of the incoming popularity estimate still
    matches the top-2k set of the popularity the plan was built from (the
    same §5.2 drift criterion as the phase-2 fine-tune check).  On drift the
    entry is invalidated and the caller re-plans.
    """

    top_k: int = 1
    _plans: Dict[int, PlacementPlan] = field(default_factory=dict)
    stats: PlanCacheStats = field(default_factory=PlanCacheStats)

    def lookup(self, layer: int, popularity: np.ndarray
               ) -> Optional[PlacementPlan]:
        plan = self._plans.get(layer)
        if plan is None:
            self.stats.misses += 1
            return None
        if top2k_sets_match(plan.popularity, popularity, self.top_k):
            self.stats.hits += 1
            return plan
        self.stats.invalidations += 1
        self.stats.misses += 1
        del self._plans[layer]
        return None

    def store(self, layer: int, plan: PlacementPlan) -> None:
        self._plans[layer] = plan

    def invalidate_devices(self, dead_devices) -> int:
        """Drop every cached plan that places an expert on a dead device —
        the failure-time companion of the drift invalidation.  Returns the
        number of entries dropped."""
        dead = [int(d) for d in dead_devices]
        doomed = [layer for layer, plan in self._plans.items()
                  if any(0 <= d < plan.n_devices
                         and (plan.slot_expert[d] >= 0).any() for d in dead)]
        for layer in doomed:
            del self._plans[layer]
        self.stats.device_invalidations += len(doomed)
        return len(doomed)

    def clear(self) -> None:
        self._plans.clear()
