"""Lina core: the paper's contribution as composable JAX modules.

Training (§4): ``moe.moe_layer`` — expert-parallel MoE with a2a micro-ops
pipelined against the expert FFN; ``microop.prioritized_chunked_reduce`` —
gradient reduction micro-ops statically ordered after a2a.

Inference (§5): ``popularity.PathProfile`` — sample-path expert-popularity
estimation; ``placement.two_phase_plan`` — Eq. 1 + FFD replication/packing;
``serving.serve_moe_layer`` — plan-aware dispatch.
"""
from repro.core.gating import GatingResult, capacity, top_k_gating
from repro.core.moe import MoEParams, MoEOutput, init_moe_params, moe_layer, expert_ffn
from repro.core.microop import (
    chunked_all_to_all, pipelined_expert_ffn, prioritized_chunked_reduce,
    ordered_after, all_to_all_ec, all_to_all_ec_inverse,
)
from repro.core.popularity import (PathProfile, rolling_path_id,
                                   estimation_accuracy, top2k_sets_match)
from repro.core.placement import (
    PlacementPlan, PlanCache, PlanCacheStats, plan_placement, identity_plan,
    needs_finetune, two_phase_plan,
)
from repro.core.packing import choose_packing, PackingDecision
from repro.core.serving import (PlanArrays, serve_moe_layer, route_to_slots,
                                slot_capacity)
