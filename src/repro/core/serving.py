"""Serving-side MoE layer with Lina placement (replicated/packed experts).

Where training dispatch routes a token to *the* device owning its expert,
serving dispatch routes to one of the expert's replica slots per the
``PlacementPlan``, and each device computes every expert packed in its
sub-slots.  Weight movement is expressed as a gather of each device's
hosted experts (the SPMD analogue of §6.2's weight swap; XLA lowers it to
the minimal collective).

Replica selection (§5/§6.2) supports two modes:

  * ``"weighted"`` (default) — per-(expert, replica) integer routing
    weights are derived from the *realized* post-gating histogram and the
    plan's ``route_weight`` columns (device-load-aware fractions from
    ``placement.route_weights``), then each kept (token, choice) is mapped
    onto its replica bin by GShard priority position
    (``kernels.ops.weighted_route_op``).  Zero migration: tokens rebalance
    within the resident placement, and the per-slot capacity recount
    disappears — integer weights are capped at ``slot_cap`` by
    construction.
  * ``"round_robin"`` — the PR-1 positional round-robin (kept as the
    ablation baseline and for heterogeneous legacy plans).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.core import axes
from repro.core.axes import EP_AXIS
from repro.core.gating import capacity, router_top_k_gating
from repro.core.moe import MoEParams, expert_ffn
from repro.core.placement import PlacementPlan
from repro.kernels import ops as kernel_ops
from repro.kernels.dispatch import invert_slots


class PlanArrays(NamedTuple):
    """Device-resident form of a PlacementPlan (static shapes).

    A *stacked* PlanArrays carries one plan per MoE layer with a leading
    layer dim on every leaf (``slot_expert.ndim == 3``); ``decode_step``
    scans over it so each layer group dispatches under its own plan.

    ``route_weight`` holds the per-(expert, replica) routing fractions the
    weighted split starts from (rows sum to 1 over live replicas, 0 on
    pads/dead columns); per batch, ``balanced_route_fractions`` rebalances
    them against the realized histogram and ``integer_route_weights`` turns
    the result into integer targets.
    """
    slot_expert: jax.Array   # [n_dev, S] int32       (stacked: [L, n_dev, S])
    replica_of: jax.Array    # [E, R] int32 flat slot ids  (stacked: [L, E, R])
    n_replicas: jax.Array    # [E] int32                   (stacked: [L, E])
    route_weight: jax.Array = None  # [E, R] f32           (stacked: [L, E, R])
    #   None only transiently (legacy 3-field construction) — serve_moe_layer
    #   substitutes the uniform split before anything enters jit

    @classmethod
    def from_plan(cls, plan: PlacementPlan) -> "PlanArrays":
        from repro.core.placement import route_weights
        return cls(jnp.asarray(plan.slot_expert), jnp.asarray(plan.replica_of),
                   jnp.asarray(plan.n_replicas),
                   jnp.asarray(route_weights(plan)))

    @property
    def stacked(self) -> bool:
        return self.slot_expert.ndim == 3


def uniform_route_weight(replica_of, n_replicas):
    """[E, R] fractions splitting each expert evenly over its live replicas
    (the weight table callers use when no PlacementPlan is in hand)."""
    replica_of = jnp.asarray(replica_of)
    n_replicas = jnp.asarray(n_replicas)
    e, r_w = replica_of.shape
    live = jnp.arange(r_w)[None, :] < jnp.clip(n_replicas, 1, r_w)[:, None]
    live = live & (replica_of >= 0)
    n_live = jnp.maximum(jnp.sum(live, axis=1, keepdims=True), 1)
    return jnp.where(live, 1.0 / n_live.astype(jnp.float32), 0.0)


def mask_dead_route_weights(route_weight, replica_of, s_pack, dead_devices,
                            xp=jnp):
    """Zero the route-weight columns of replicas hosted on dead devices and
    renormalize each row over the survivors — the zero-migration degradation
    path: in-flight decodes re-route around a failed device with no plan
    rebuild and no slot-state loss, because the weighted split drops
    zero-weight bins entirely (``weighted_route`` keeps only positions below
    the cumulative row total).

    Rows whose every replica is dead come back all-zero; callers must
    emergency-replan those experts (``MoEServer.fail_devices`` does).
    Accepts flat [E, R] or stacked [L, E, R] tables.
    """
    dead = sorted(int(d) for d in dead_devices)
    if not dead:
        return route_weight
    dev = xp.where(replica_of >= 0, replica_of // s_pack, -1)
    doomed = xp.zeros(dev.shape, bool)
    for d in dead:
        doomed = doomed | (dev == d)
    w = xp.where(doomed, 0.0, route_weight.astype(xp.float32))
    tot = xp.sum(w, axis=-1, keepdims=True)
    return xp.where(tot > 0, w / xp.maximum(tot, 1e-9), 0.0)


def stack_plan_arrays(plans) -> PlanArrays:
    """Stack per-layer plans (PlacementPlan or PlanArrays) into one stacked
    PlanArrays with a leading layer dim.  All plans must agree on device
    count and sub-slot count; replica tables are right-padded to the widest
    plan (-1 slot ids, 0.0 route weights) so the stack is rectangular."""
    arrs = [p if isinstance(p, PlanArrays) else PlanArrays.from_plan(p)
            for p in plans]
    assert arrs, "stack_plan_arrays needs at least one plan"
    shapes = {a.slot_expert.shape for a in arrs}
    assert len(shapes) == 1, f"plans disagree on device layout: {shapes}"
    r = max(a.replica_of.shape[1] for a in arrs)

    def pad(a, fill):
        w = r - a.shape[1]
        return a if not w else jnp.pad(a, ((0, 0), (0, w)),
                                       constant_values=fill)

    def rweight(a):
        if a.route_weight is not None:
            return a.route_weight
        return uniform_route_weight(a.replica_of, a.n_replicas)

    return PlanArrays(
        jnp.stack([a.slot_expert for a in arrs]),
        jnp.stack([pad(a.replica_of, -1) for a in arrs]),
        jnp.stack([a.n_replicas for a in arrs]),
        jnp.stack([pad(rweight(a), 0.0) for a in arrs]))


def route_to_slots(expert_idx: jax.Array, position: jax.Array,
                   plan: PlanArrays) -> jax.Array:
    """[T, k] expert choices -> [T, k] flat slot ids, round-robin over the
    expert's replicas by buffer position (balances links, §5/§6.2).

    ``n_replicas`` is clamped to the live replica-table width: a stacked
    plan is right-padded with -1 slot ids, and a layer whose replica count
    disagrees with the pad width must never index a pad column.  A -1 slot
    can still surface if the plan itself is inconsistent (n_replicas >
    genuine table entries) — callers must treat ``slot < 0`` as dropped.
    """
    r_w = plan.replica_of.shape[-1]
    n_rep = jnp.clip(plan.n_replicas[expert_idx], 1, r_w)      # [T, k]
    which = position % n_rep
    return jnp.take_along_axis(plan.replica_of[expert_idx], which[..., None],
                               axis=-1)[..., 0]


def integer_route_weights(counts, route_weight, n_replicas, slot_cap,
                          xp=jnp):
    """Realized per-expert token counts -> per-(expert, replica) integer
    routing weights (the §5 weighted zero-migration split).

    counts: [E] int kept tokens per expert this batch; route_weight: [E, R]
    fractions (0 on dead/pad columns); n_replicas: [E]; slot_cap: rows per
    slot.  Returns [E, R] int32 with

      * 0 on dead/pad columns, every entry <= slot_cap,
      * row sums >= counts whenever counts <= slot_cap * live replicas
        (no token is dropped by the split itself),
      * each entry within +-1 of its fractional target counts * frac
        (largest-remainder apportionment), except where the slot_cap clamp
        forces spill into other replicas' headroom.

    Pure elementwise/int math shared by the jit path (``xp=jnp``) and the
    host telemetry mirror (``xp=numpy``) — deliberately argsort-free so
    both backends rank remainders identically.
    """
    e, r_w = route_weight.shape
    counts = counts.astype(xp.int32)
    live = xp.arange(r_w, dtype=xp.int32)[None, :] \
        < xp.clip(n_replicas, 1, r_w).astype(xp.int32)[:, None]
    frac = xp.where(live, route_weight.astype(xp.float32), 0.0)
    tot = xp.sum(frac, axis=1, keepdims=True)
    # a column whose fraction is exactly 0 was deliberately zeroed (dead
    # device) and must get no remainder/spill tokens; a fully-zeroed row
    # keeps its replicas so the uniform fallback never drops tokens here —
    # the server emergency-replans such experts off the dead devices.
    live = live & ((frac > 0.0) | (tot <= 1e-9))
    n_live = xp.maximum(xp.sum(live.astype(xp.int32), axis=1, keepdims=True),
                        1)
    uniform = xp.where(live, 1.0 / n_live.astype(xp.float32), 0.0)
    frac = xp.where(tot > 1e-9, frac / xp.maximum(tot, 1e-9), uniform)
    quota = counts[:, None].astype(xp.float32) * frac
    base = xp.floor(quota).astype(xp.int32)
    fp = xp.where(live, quota - base.astype(xp.float32), -1.0)
    # largest-remainder rank[e, r] = #{r' : fp[r'] > fp[r], ties to lower
    # index} via an [E, R, R] comparison count (argsort stability differs
    # between numpy and jax; this does not)
    idx_r = xp.arange(r_w, dtype=xp.int32)
    beats = (fp[:, None, :] > fp[:, :, None]) | \
        ((fp[:, None, :] == fp[:, :, None])
         & (idx_r[None, None, :] < idx_r[None, :, None]))
    rank = xp.sum(beats.astype(xp.int32), axis=2)               # [E, R]
    rem = xp.maximum(counts - xp.sum(base, axis=1), 0)
    base = base + ((rank < rem[:, None]) & live).astype(xp.int32)
    base = xp.minimum(base, slot_cap)
    # pour any shortfall (slot_cap clamp, fp rounding) into live headroom,
    # left to right — guarantees row sums cover counts whenever possible
    head = xp.where(live, slot_cap - base, 0)
    short = xp.maximum(counts - xp.sum(base, axis=1), 0)
    cum_prev = xp.cumsum(head, axis=1) - head
    add = xp.clip(short[:, None] - cum_prev, 0, head)
    return (base + add).astype(xp.int32)


def balanced_route_fractions(counts, route_weight, replica_of, n_replicas,
                             n_dev, s_pack, rounds=4, xp=jnp):
    """Realized per-expert token counts -> per-(expert, replica) fractions
    that balance THIS batch's per-device received tokens over the resident
    placement — §5's transfer-balance objective evaluated on the realized
    histogram rather than the plan's popularity basis.

    The plan's static ``route_weight`` (IPF on the basis popularity) seeds
    a few multiplicative rebalance rounds against ``counts``: single-replica
    experts are pinned mass the balance works around, and a stale basis
    (drift) is corrected instead of amplified — an even split is what the
    balance converges to when the placement is symmetric, so this never
    does worse than round-robin in expectation.  ``replica_of`` holds flat
    slot ids over an [n_dev, s_pack] slot grid (device = slot // s_pack).
    Pure elementwise/int-gather math shared by the jit path (``xp=jnp``)
    and the host telemetry mirror (``xp=numpy``).
    """
    e, r_w = replica_of.shape
    live = (xp.arange(r_w, dtype=xp.int32)[None, :]
            < xp.clip(n_replicas, 1, r_w).astype(xp.int32)[:, None]) \
        & (replica_of >= 0)
    dev = xp.where(live, replica_of // s_pack, 0)
    # seed: plan fractions floored away from 0 so the multiplicative update
    # can recover a column the prior starved.  A column whose weight is
    # *exactly* 0 was deliberately zeroed (dead device / pad — IPF and the
    # uniform split never emit exact zeros on live columns) and must stay 0.
    live = live & (route_weight > 0)
    w = xp.where(live, xp.maximum(route_weight.astype(xp.float32), 1e-6), 0.0)
    tot = xp.sum(w, axis=1, keepdims=True)
    w = xp.where(tot > 0, w / xp.maximum(tot, 1e-9), 0.0)
    c = counts.astype(xp.float32)[:, None]                        # [E, 1]
    target = xp.maximum(xp.sum(c) / n_dev, 1e-9)
    oh = (dev.reshape(-1)[:, None]
          == xp.arange(n_dev, dtype=xp.int32)[None, :]).astype(xp.float32)
    for _ in range(rounds):
        load = (w * c).reshape(-1) @ oh                           # [n_dev]
        fac = xp.clip(target / xp.maximum(load, 1e-9), 0.1, 10.0)
        w = xp.where(live, w * fac[dev], 0.0)
        w = w / xp.maximum(xp.sum(w, axis=1, keepdims=True), 1e-9)
    return w


def slot_capacity(cap: int, min_replicas: int) -> int:
    """Per (device, sub-slot) buffer capacity under replication.

    An expert with r replicas round-robins its <= ``cap`` tokens over r
    slots, so each slot needs only ceil(cap / r); sizing by the *minimum*
    replica count across hosted experts is safe for every slot.  Floored at
    8 to keep the scatter MXU-aligned.  Must be static (shapes depend on
    it), hence an int argument rather than a plan-array lookup.
    """
    return max(8, -(-cap // max(1, min_replicas)))


def dp_shard_count(mesh, n_tokens: int) -> int:
    """The data-parallel factor ``serve_moe_layer`` shards tokens by (1 when
    the token count does not tile the dp axes)."""
    if mesh is None:
        return 1
    sizes = axes.axis_sizes(mesh)
    dp_n = sizes.get(axes.POD, 1) * sizes.get(axes.DATA, 1)
    return dp_n if n_tokens % dp_n == 0 else 1


def _serve_body(x, router, wi, wu, wo, plan: PlanArrays, *, cfg: MoEConfig,
                ffn_type: str, ep_axis: str, top_k: int,
                min_replicas: int = 1, cap_override: int = 0,
                route_mode: str = "weighted"):
    """x: [T_local, d]; wi/wu/wo sharded expert-major over ep_axis."""
    t_local, d_model = x.shape
    e = cfg.n_experts
    ep = lax.psum(1, ep_axis)
    n_dev, s_pack = plan.slot_expert.shape
    cap = cap_override or capacity(t_local, e, top_k, cfg.capacity_factor)
    slot_cap = slot_capacity(cap, min_replicas)

    backend = kernel_ops.resolve_backend(cfg.compute_backend)
    # gating capacity stays per-expert (cap); the per-slot limit is enforced
    # by the replica split below.  The router matmul (and on the pallas
    # backend the position cumsum) is fused into the gating kernels.
    g = router_top_k_gating(x, router, top_k, cap, cfg.aux_loss_weight,
                            compute_backend=backend)

    # --- route to replica slots instead of home experts -------------------
    n_slots = n_dev * s_pack
    if route_mode == "weighted":
        # realized histogram -> integer per-replica targets -> bin routing.
        # Kept positions for expert e are exactly {0..counts_e-1} (GShard
        # priority), so position < sum(w_int) IS the capacity rule and no
        # per-slot recount is needed: every replica bin holds <= slot_cap.
        kept = (~g.dropped).astype(jnp.int32)
        counts = jnp.zeros((e,), jnp.int32).at[g.expert_idx.reshape(-1)] \
            .add(kept.reshape(-1), mode="drop")
        fracs = balanced_route_fractions(counts, plan.route_weight,
                                         plan.replica_of, plan.n_replicas,
                                         n_dev, s_pack)
        w_int = integer_route_weights(counts, fracs, plan.n_replicas,
                                      slot_cap)
        cumw = jnp.cumsum(w_int, axis=1).astype(jnp.int32)
        rows = kernel_ops.weighted_route_op(
            jnp.where(g.dropped, -1, g.expert_idx), g.position, cumw,
            plan.replica_of, slot_cap,
            use_pallas=(backend == "pallas"))                   # [T, k]
        dropped = rows < 0
    else:
        slots = route_to_slots(g.expert_idx, g.position, plan)  # [T, k]
        # position within the slot: recount capacity per slot
        oh = jax.nn.one_hot(slots, n_slots, dtype=jnp.int32)
        pos = (jnp.cumsum(oh.reshape(-1, n_slots), axis=0)
               - oh.reshape(-1, n_slots))
        pos = jnp.sum(pos.reshape(*slots.shape, n_slots) * oh, axis=-1)
        # slots < 0: inconsistent plan (n_replicas past the live table) —
        # treat as dropped rather than scattering into a negative row
        dropped = g.dropped | (pos >= slot_cap) | (slots < 0)

        # single source of truth for the slot-row map: -1 encodes dropped
        rows = jnp.where(dropped, -1, slots * slot_cap + pos)   # [T, k]
    if backend == "pallas":
        src_tok, _ = invert_slots(rows, n_slots * slot_cap)
        disp, _ = kernel_ops.dispatch_combine_op(use_pallas=True)
        buf = disp(x, src_tok, rows)
    else:
        flat_idx = jnp.where(rows < 0, n_slots * slot_cap, rows)
        buf = jnp.zeros((n_slots * slot_cap + 1, d_model), x.dtype)
        src = jnp.broadcast_to(x[:, None, :], (*rows.shape, d_model))
        buf = buf.at[flat_idx.reshape(-1)].set(src.reshape(-1, d_model),
                                               mode="drop")[:-1]
    buf = buf.reshape(n_dev, s_pack * slot_cap, d_model)

    # --- a2a to slot owners ------------------------------------------------
    # n_dev logical devices map onto ep physical ranks (group = n_dev/ep
    # logical per physical; group == 1 on the production mesh, == n_dev on a
    # single CPU device so the same code serves tests and demos)
    assert n_dev % ep == 0, "plan devices must tile the EP group"
    group = n_dev // ep
    recv = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                          tiled=True)                 # [ep*grp*S*cap, d] mine
    recv = recv.reshape(ep, group * s_pack, slot_cap, d_model)

    # --- hosted-expert weights (gather = §6.2 weight swap) -----------------
    my_dev = lax.axis_index(ep_axis)
    hosted = lax.dynamic_slice_in_dim(plan.slot_expert, my_dev * group,
                                      group, 0).reshape(group * s_pack)
    s_pack = group * s_pack
    e_local = e // ep
    # wi is the local shard [E_local, d, f]; hosted experts may live on other
    # shards -> gather the full stacks then select (XLA keeps only used rows
    # alive; the optimized delta-fetch path is a §Perf hillclimb).
    wi_full = lax.all_gather(wi, ep_axis, axis=0, tiled=True)     # [E, d, f]
    wo_full = lax.all_gather(wo, ep_axis, axis=0, tiled=True)
    wu_full = lax.all_gather(wu, ep_axis, axis=0, tiled=True) if wu is not None else None
    safe = jnp.maximum(hosted, 0)
    wi_h = wi_full[safe]
    wo_h = wo_full[safe]
    wu_h = wu_full[safe] if wu_full is not None else None

    # --- compute packed experts sequentially (§6.2) ------------------------
    # replica-packed [S, n, d] slot buffers feed the same grouped-FFN op the
    # training layer uses (the Pallas grouped GEMM on that backend)
    toks = recv.transpose(1, 0, 2, 3).reshape(s_pack, ep * slot_cap, d_model)
    out = expert_ffn(wi_h, wu_h, wo_h, toks, ffn_type, backend)   # [S, n, d]
    out = out * (hosted >= 0)[:, None, None]
    out = out.reshape(s_pack, ep, slot_cap, d_model).transpose(1, 0, 2, 3)

    # --- a2a back + combine -------------------------------------------------
    back = lax.all_to_all(out.reshape(ep, s_pack * slot_cap, d_model),
                          ep_axis, split_axis=0, concat_axis=0, tiled=True)
    flat = back.reshape(n_slots * slot_cap, d_model)
    w = jnp.where(dropped, 0.0, g.gate_weights)
    if backend == "pallas":
        _, comb = kernel_ops.dispatch_combine_op(use_pallas=True)
        y = comb(flat, rows, w).astype(x.dtype)
    else:
        vals = flat[jnp.maximum(rows, 0)]    # dropped gather row 0, w == 0
        y = jnp.sum(vals.astype(jnp.float32) * w[..., None],
                    axis=1).astype(x.dtype)
    return y, g.expert_idx, g.router_probs


def serve_moe_layer(mesh, x, params: MoEParams, cfg: MoEConfig,
                    plan: PlanArrays, *, ffn_type: str = "swiglu",
                    top_k: int | None = None, min_replicas: int = 1,
                    cap_override: int = 0, route_mode: str = "weighted"):
    """Inference MoE layer honoring a placement plan.  x: [T, d] global.

    ``min_replicas`` is the minimum live replica count across experts in
    ``plan`` (static; callers with a host-side PlacementPlan pass
    ``int(plan.n_replicas.min())``) — it shrinks per-slot buffers to
    ceil(cap / min_replicas).  ``cap_override`` (static, per-device) pins
    the per-expert gating capacity; callers serving right-padded batches
    use it to size capacity from the *valid* token count so padding rows
    cannot change real tokens' dispatch.  ``route_mode`` selects the
    replica split: ``"weighted"`` (realized-histogram integer weights,
    zero-migration §5 rebalance) or ``"round_robin"`` (positional).
    """
    if mesh is None:
        from repro.core.moe import default_mesh
        mesh = default_mesh()
    if route_mode not in ("weighted", "round_robin"):
        raise ValueError(f"unknown route_mode {route_mode!r}")
    dp = axes.dp_axes(mesh)
    dp_n = dp_shard_count(mesh, x.shape[0])
    bspec = P(dp, None) if dp_n > 1 else P(None, None)
    wspec = P(EP_AXIS, None, None)
    k = top_k if top_k is not None else max(cfg.top_k, 1)
    has_wu = params.wu is not None
    wu = params.wu if has_wu else jnp.zeros((), x.dtype)
    rweight = plan.route_weight
    if rweight is None:       # legacy plan tuples: split live replicas evenly
        rweight = uniform_route_weight(plan.replica_of, plan.n_replicas)

    def wrapped(x, router, wi, wu_, wo, se, ro, nr, rw):
        plan_arr = PlanArrays(se, ro, nr, rw)
        return _serve_body(x, router, wi, wu_ if has_wu else None, wo,
                           plan_arr, cfg=cfg, ffn_type=ffn_type,
                           ep_axis=EP_AXIS, top_k=k,
                           min_replicas=min_replicas,
                           cap_override=cap_override,
                           route_mode=route_mode)

    y, eidx, probs = shard_map(
        wrapped, mesh=mesh,
        in_specs=(bspec, P(None, None), wspec, wspec if has_wu else P(),
                  wspec, P(None, None), P(None, None), P(None),
                  P(None, None)),
        out_specs=(bspec, bspec, bspec),
        check_rep=False,
    )(x, params.router, params.wi, wu, params.wo,
      plan.slot_expert, plan.replica_of, plan.n_replicas, rweight)
    return y, eidx, probs


def _np_positions(expert_idx: np.ndarray, n_experts: int) -> np.ndarray:
    """Choice-major GShard priority rank, numpy (mirror of
    ``ref.ref_topk_positions``); -1 entries rank 0 and advance nothing."""
    t, k = expert_idx.shape
    flat = expert_idx.T.reshape(-1)
    oh = (flat[:, None] == np.arange(n_experts)[None, :]).astype(np.int64)
    pos = ((np.cumsum(oh, axis=0) - oh) * oh).sum(1)
    return pos.reshape(k, t).T


def replica_token_counts(expert_idx, plan: PlanArrays, cap: int,
                         slot_cap: int, *, valid=None, dp_shards: int = 1,
                         route_mode: str = "weighted") -> np.ndarray:
    """Host-side mirror of the device routing: realized *valid* token count
    per (device, sub-slot) under ``plan`` — the per-replica load the
    telemetry bus/controller observes (satellite of the §5 weighted split).

    expert_idx: [T, k] host ints (the server's gate output over the full
    padded batch — padding rows DO claim capacity on device and are
    mirrored here, they just aren't counted); valid: optional [T] bool;
    dp_shards: the data-parallel factor ``serve_moe_layer`` used (tokens
    route within their shard).  Returns [n_slots] int64.
    """
    idx = np.asarray(expert_idx, np.int32)
    se = np.asarray(plan.slot_expert)
    ro = np.asarray(plan.replica_of, np.int32)
    nr = np.asarray(plan.n_replicas, np.int32)
    rw_tab = plan.route_weight
    if rw_tab is None:
        rw_tab = uniform_route_weight(ro, nr)
    rw_tab = np.asarray(rw_tab, np.float32)
    e, r_w = ro.shape
    n_slots = int(se.size)
    t = idx.shape[0]
    v = np.ones(t, bool) if valid is None else np.asarray(valid, bool)
    shards = max(1, int(dp_shards))
    if t % shards:
        shards = 1
    out = np.zeros(n_slots, np.int64)
    for chunk, vc in zip(np.split(idx, shards, axis=0),
                         np.split(v, shards, axis=0)):
        pos = _np_positions(chunk, e).astype(np.int32)
        dropped = (chunk < 0) | (pos >= cap)
        counts = np.bincount(chunk[~dropped].reshape(-1),
                             minlength=e).astype(np.int32)[:e]
        if route_mode == "weighted":
            from repro.kernels import ref
            n_dev_m, s_pack_m = se.shape
            fr = balanced_route_fractions(counts, rw_tab, ro, nr, n_dev_m,
                                          s_pack_m, xp=np)
            w_int = integer_route_weights(counts, fr, nr, slot_cap, xp=np)
            cum = np.cumsum(w_int, axis=1).astype(np.int32)
            rows = ref.ref_weighted_route(np.where(dropped, -1, chunk),
                                          pos, cum, ro, slot_cap, xp=np)
            keep = (rows >= 0) & vc[:, None]
            slots = rows[keep] // slot_cap
        else:
            safe = np.maximum(chunk, 0)
            n_rep = np.clip(nr[safe], 1, r_w)
            which = pos % n_rep
            sl = np.take_along_axis(ro[safe], which[..., None],
                                    axis=-1)[..., 0]
            # the device recount one-hots ALL rows (even gating-dropped
            # ones claim recount positions) — mirror that exactly
            flat = sl.reshape(-1)                           # token-major
            soh = (flat[:, None] == np.arange(n_slots)[None, :])
            spos = ((np.cumsum(soh, axis=0) - soh) * soh).sum(1) \
                .reshape(chunk.shape)
            keep = ~dropped & (sl >= 0) & (spos < slot_cap) & vc[:, None]
            slots = sl[keep]
        out += np.bincount(slots, minlength=n_slots)[:n_slots]
    return out
