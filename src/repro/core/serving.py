"""Serving-side MoE layer with Lina placement (replicated/packed experts).

Where training dispatch routes a token to *the* device owning its expert,
serving dispatch routes to one of the expert's replica slots per the
``PlacementPlan`` (balanced round-robin by intra-expert position), and each
device computes every expert packed in its sub-slots.  Weight movement is
expressed as a gather of each device's hosted experts (the SPMD analogue of
§6.2's weight swap; XLA lowers it to the minimal collective).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.core import axes
from repro.core.axes import EP_AXIS
from repro.core.gating import capacity, router_top_k_gating
from repro.core.moe import MoEParams, expert_ffn
from repro.core.placement import PlacementPlan
from repro.kernels import ops as kernel_ops
from repro.kernels.dispatch import invert_slots


class PlanArrays(NamedTuple):
    """Device-resident form of a PlacementPlan (static shapes).

    A *stacked* PlanArrays carries one plan per MoE layer with a leading
    layer dim on every leaf (``slot_expert.ndim == 3``); ``decode_step``
    scans over it so each layer group dispatches under its own plan.
    """
    slot_expert: jax.Array   # [n_dev, S] int32       (stacked: [L, n_dev, S])
    replica_of: jax.Array    # [E, R] int32 flat slot ids  (stacked: [L, E, R])
    n_replicas: jax.Array    # [E] int32                   (stacked: [L, E])

    @classmethod
    def from_plan(cls, plan: PlacementPlan) -> "PlanArrays":
        return cls(jnp.asarray(plan.slot_expert), jnp.asarray(plan.replica_of),
                   jnp.asarray(plan.n_replicas))

    @property
    def stacked(self) -> bool:
        return self.slot_expert.ndim == 3


def stack_plan_arrays(plans) -> PlanArrays:
    """Stack per-layer plans (PlacementPlan or PlanArrays) into one stacked
    PlanArrays with a leading layer dim.  All plans must agree on device
    count and sub-slot count; replica tables are right-padded with -1 to the
    widest plan so the stack is rectangular."""
    arrs = [p if isinstance(p, PlanArrays) else PlanArrays.from_plan(p)
            for p in plans]
    assert arrs, "stack_plan_arrays needs at least one plan"
    shapes = {a.slot_expert.shape for a in arrs}
    assert len(shapes) == 1, f"plans disagree on device layout: {shapes}"
    r = max(a.replica_of.shape[1] for a in arrs)

    def pad(a):
        w = r - a.shape[1]
        return a if not w else jnp.pad(a, ((0, 0), (0, w)),
                                       constant_values=-1)

    return PlanArrays(
        jnp.stack([a.slot_expert for a in arrs]),
        jnp.stack([pad(a.replica_of) for a in arrs]),
        jnp.stack([a.n_replicas for a in arrs]))


def route_to_slots(expert_idx: jax.Array, position: jax.Array,
                   plan: PlanArrays) -> jax.Array:
    """[T, k] expert choices -> [T, k] flat slot ids, round-robin over the
    expert's replicas by buffer position (balances links, §5/§6.2)."""
    n_rep = jnp.maximum(plan.n_replicas[expert_idx], 1)        # [T, k]
    which = position % n_rep
    return jnp.take_along_axis(plan.replica_of[expert_idx], which[..., None],
                               axis=-1)[..., 0]


def slot_capacity(cap: int, min_replicas: int) -> int:
    """Per (device, sub-slot) buffer capacity under replication.

    An expert with r replicas round-robins its <= ``cap`` tokens over r
    slots, so each slot needs only ceil(cap / r); sizing by the *minimum*
    replica count across hosted experts is safe for every slot.  Floored at
    8 to keep the scatter MXU-aligned.  Must be static (shapes depend on
    it), hence an int argument rather than a plan-array lookup.
    """
    return max(8, -(-cap // max(1, min_replicas)))


def dp_shard_count(mesh, n_tokens: int) -> int:
    """The data-parallel factor ``serve_moe_layer`` shards tokens by (1 when
    the token count does not tile the dp axes)."""
    if mesh is None:
        return 1
    sizes = axes.axis_sizes(mesh)
    dp_n = sizes.get(axes.POD, 1) * sizes.get(axes.DATA, 1)
    return dp_n if n_tokens % dp_n == 0 else 1


def _serve_body(x, router, wi, wu, wo, plan: PlanArrays, *, cfg: MoEConfig,
                ffn_type: str, ep_axis: str, top_k: int,
                min_replicas: int = 1, cap_override: int = 0):
    """x: [T_local, d]; wi/wu/wo sharded expert-major over ep_axis."""
    t_local, d_model = x.shape
    e = cfg.n_experts
    ep = lax.psum(1, ep_axis)
    n_dev, s_pack = plan.slot_expert.shape
    cap = cap_override or capacity(t_local, e, top_k, cfg.capacity_factor)
    slot_cap = slot_capacity(cap, min_replicas)

    backend = kernel_ops.resolve_backend(cfg.compute_backend)
    # gating capacity stays per-expert (cap); the per-slot limit is enforced
    # below after tokens are spread over the expert's replicas.  The router
    # matmul is fused into the gating kernel on the pallas backend.
    g = router_top_k_gating(x, router, top_k, cap, cfg.aux_loss_weight,
                            compute_backend=backend)

    # --- route to replica slots instead of home experts -------------------
    slots = route_to_slots(g.expert_idx, g.position, plan)      # [T, k]
    n_slots = n_dev * s_pack
    # position within the slot: recount capacity per slot
    oh = jax.nn.one_hot(slots, n_slots, dtype=jnp.int32)
    pos = (jnp.cumsum(oh.reshape(-1, n_slots), axis=0) - oh.reshape(-1, n_slots))
    pos = jnp.sum(pos.reshape(*slots.shape, n_slots) * oh, axis=-1)
    dropped = g.dropped | (pos >= slot_cap)

    # single source of truth for the slot-row map: -1 encodes dropped
    rows = jnp.where(dropped, -1, slots * slot_cap + pos)       # [T, k]
    if backend == "pallas":
        src_tok, _ = invert_slots(rows, n_slots * slot_cap)
        disp, _ = kernel_ops.dispatch_combine_op(use_pallas=True)
        buf = disp(x, src_tok, rows)
    else:
        flat_idx = jnp.where(rows < 0, n_slots * slot_cap, rows)
        buf = jnp.zeros((n_slots * slot_cap + 1, d_model), x.dtype)
        src = jnp.broadcast_to(x[:, None, :], (*slots.shape, d_model))
        buf = buf.at[flat_idx.reshape(-1)].set(src.reshape(-1, d_model),
                                               mode="drop")[:-1]
    buf = buf.reshape(n_dev, s_pack * slot_cap, d_model)

    # --- a2a to slot owners ------------------------------------------------
    # n_dev logical devices map onto ep physical ranks (group = n_dev/ep
    # logical per physical; group == 1 on the production mesh, == n_dev on a
    # single CPU device so the same code serves tests and demos)
    assert n_dev % ep == 0, "plan devices must tile the EP group"
    group = n_dev // ep
    recv = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                          tiled=True)                 # [ep*grp*S*cap, d] mine
    recv = recv.reshape(ep, group * s_pack, slot_cap, d_model)

    # --- hosted-expert weights (gather = §6.2 weight swap) -----------------
    my_dev = lax.axis_index(ep_axis)
    hosted = lax.dynamic_slice_in_dim(plan.slot_expert, my_dev * group,
                                      group, 0).reshape(group * s_pack)
    s_pack = group * s_pack
    e_local = e // ep
    # wi is the local shard [E_local, d, f]; hosted experts may live on other
    # shards -> gather the full stacks then select (XLA keeps only used rows
    # alive; the optimized delta-fetch path is a §Perf hillclimb).
    wi_full = lax.all_gather(wi, ep_axis, axis=0, tiled=True)     # [E, d, f]
    wo_full = lax.all_gather(wo, ep_axis, axis=0, tiled=True)
    wu_full = lax.all_gather(wu, ep_axis, axis=0, tiled=True) if wu is not None else None
    safe = jnp.maximum(hosted, 0)
    wi_h = wi_full[safe]
    wo_h = wo_full[safe]
    wu_h = wu_full[safe] if wu_full is not None else None

    # --- compute packed experts sequentially (§6.2) ------------------------
    # replica-packed [S, n, d] slot buffers feed the same grouped-FFN op the
    # training layer uses (the Pallas grouped GEMM on that backend)
    toks = recv.transpose(1, 0, 2, 3).reshape(s_pack, ep * slot_cap, d_model)
    out = expert_ffn(wi_h, wu_h, wo_h, toks, ffn_type, backend)   # [S, n, d]
    out = out * (hosted >= 0)[:, None, None]
    out = out.reshape(s_pack, ep, slot_cap, d_model).transpose(1, 0, 2, 3)

    # --- a2a back + combine -------------------------------------------------
    back = lax.all_to_all(out.reshape(ep, s_pack * slot_cap, d_model),
                          ep_axis, split_axis=0, concat_axis=0, tiled=True)
    flat = back.reshape(n_slots * slot_cap, d_model)
    w = jnp.where(dropped, 0.0, g.gate_weights)
    if backend == "pallas":
        _, comb = kernel_ops.dispatch_combine_op(use_pallas=True)
        y = comb(flat, rows, w).astype(x.dtype)
    else:
        vals = flat[jnp.maximum(rows, 0)]    # dropped gather row 0, w == 0
        y = jnp.sum(vals.astype(jnp.float32) * w[..., None],
                    axis=1).astype(x.dtype)
    return y, g.expert_idx, g.router_probs


def serve_moe_layer(mesh, x, params: MoEParams, cfg: MoEConfig,
                    plan: PlanArrays, *, ffn_type: str = "swiglu",
                    top_k: int | None = None, min_replicas: int = 1,
                    cap_override: int = 0):
    """Inference MoE layer honoring a placement plan.  x: [T, d] global.

    ``min_replicas`` is the minimum live replica count across experts in
    ``plan`` (static; callers with a host-side PlacementPlan pass
    ``int(plan.n_replicas.min())``) — it shrinks per-slot buffers to
    ceil(cap / min_replicas).  ``cap_override`` (static, per-device) pins
    the per-expert gating capacity; callers serving right-padded batches
    use it to size capacity from the *valid* token count so padding rows
    cannot change real tokens' dispatch.
    """
    if mesh is None:
        from repro.core.moe import default_mesh
        mesh = default_mesh()
    dp = axes.dp_axes(mesh)
    dp_n = dp_shard_count(mesh, x.shape[0])
    bspec = P(dp, None) if dp_n > 1 else P(None, None)
    wspec = P(EP_AXIS, None, None)
    k = top_k if top_k is not None else max(cfg.top_k, 1)
    has_wu = params.wu is not None
    wu = params.wu if has_wu else jnp.zeros((), x.dtype)

    def wrapped(x, router, wi, wu_, wo, se, ro, nr):
        plan_arr = PlanArrays(se, ro, nr)
        return _serve_body(x, router, wi, wu_ if has_wu else None, wo,
                           plan_arr, cfg=cfg, ffn_type=ffn_type,
                           ep_axis=EP_AXIS, top_k=k,
                           min_replicas=min_replicas,
                           cap_override=cap_override)

    y, eidx, probs = shard_map(
        wrapped, mesh=mesh,
        in_specs=(bspec, P(None, None), wspec, wspec if has_wu else P(),
                  wspec, P(None, None), P(None, None), P(None)),
        out_specs=(bspec, bspec, bspec),
        check_rep=False,
    )(x, params.router, params.wi, wu, params.wo,
      plan.slot_expert, plan.replica_of, plan.n_replicas)
    return y, eidx, probs
