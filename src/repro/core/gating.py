"""Top-k gating with capacity and the Switch/GShard auxiliary load-balancing
loss (paper §2.1).

The gating network is a single trainable matrix; tokens are dispatched to the
top-k experts subject to a per-expert capacity so all shapes stay static
under SPMD (TPU requirement; matches DeepSpeed's capacity-factor dispatch that
the paper baselines against, with Random Token Dropping disabled).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class GatingResult(NamedTuple):
    expert_idx: jax.Array      # [T, k] int32 — chosen expert per token/slot
    gate_weights: jax.Array    # [T, k] — combine weights (softmax renormed)
    position: jax.Array        # [T, k] int32 — position within expert buffer
    dropped: jax.Array         # [T, k] bool — True if over capacity
    aux_loss: jax.Array        # scalar — load-balancing loss
    router_probs: jax.Array    # [T, E] — full softmax (popularity profiling)


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    """Per-expert buffer capacity, MXU-aligned up to a multiple of 8."""
    c = int(n_tokens * top_k * capacity_factor / n_experts) + 1
    return max(8, -(-c // 8) * 8)


def gating_from_topk(expert_idx: jax.Array, gate_w: jax.Array,
                     probs: jax.Array, cap: int,
                     aux_loss_weight: float = 0.01,
                     position: jax.Array | None = None) -> GatingResult:
    """Shared capacity/position/aux epilogue: turn raw top-k picks
    (idx [T,k], renormalized weights [T,k], full probs [T,E]) into the
    complete dispatch metadata.  Both the XLA gating path and the fused
    Pallas kernel (``kernels.ops.topk_gating_op``) feed this, so they agree
    exactly on slots, drops and the aux loss.

    ``position`` may be precomputed (the fused ``topk_positions`` kernel on
    the pallas path); when None the [T, k, E] one-hot cumsum runs here.
    """
    n_tokens, n_experts = probs.shape
    top_k = expert_idx.shape[1]

    # Aux loss (Switch eq.4): E * sum_e f_e * p_e, f_e from top-1 assignment.
    top1 = expert_idx[:, 0]
    f_e = jnp.mean(jax.nn.one_hot(top1, n_experts, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = aux_loss_weight * n_experts * jnp.sum(f_e * p_e)

    if position is None:
        # Capacity slots: flatten the k choices in priority order (all
        # tokens' 1st choice before any 2nd choice, GShard-style) so top-1
        # wins slots.
        onehot = jax.nn.one_hot(expert_idx, n_experts,
                                dtype=jnp.int32)                 # [T,k,E]
        flat = onehot.transpose(1, 0, 2).reshape(top_k * n_tokens, n_experts)
        pos_flat = jnp.cumsum(flat, axis=0) - flat           # pos in expert
        pos = (pos_flat.reshape(top_k, n_tokens, n_experts)
               .transpose(1, 0, 2))                              # [T,k,E]
        position = jnp.sum(pos * onehot, axis=-1)                # [T, k]
    dropped = position >= cap

    gate_w = jnp.where(dropped, 0.0, gate_w)
    return GatingResult(expert_idx.astype(jnp.int32), gate_w,
                        position.astype(jnp.int32), dropped, aux, probs)


def top_k_gating(logits: jax.Array, top_k: int, cap: int,
                 aux_loss_weight: float = 0.01,
                 rng: jax.Array | None = None,
                 jitter: float = 0.0) -> GatingResult:
    """logits: [T, E].  Returns dispatch metadata with static shapes.

    Position assignment follows GShard: tokens claim capacity slots in order
    (cumsum over the one-hot dispatch mask); tokens past the capacity are
    dropped (residual connection carries them, as in DeepSpeed).
    """
    if jitter > 0.0 and rng is not None:
        logits = logits + jitter * jax.random.normal(rng, logits.shape,
                                                     logits.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    gate_w, expert_idx = jax.lax.top_k(probs, top_k)            # [T, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    return gating_from_topk(expert_idx, gate_w, probs, cap, aux_loss_weight)


def router_top_k_gating(x: jax.Array, router: jax.Array, top_k: int,
                        cap: int, aux_loss_weight: float = 0.01, *,
                        compute_backend: str = "xla") -> GatingResult:
    """The full gating network: ``x @ router`` + softmax + top-k.

    On the pallas backend the router matmul is folded into the fused
    softmax/top-k kernel (one VMEM pass, k <= 2 on the MoE paths); the
    capacity/position/aux epilogue is shared with ``top_k_gating`` so the
    two backends produce identical GatingResults.
    """
    if compute_backend != "pallas":
        return top_k_gating(x @ router, top_k, cap, aux_loss_weight)
    from repro.kernels import ops as kernel_ops
    idx, gate_w, probs = kernel_ops.topk_gating_op(x, router, top_k,
                                                   use_pallas=True)
    # the capacity/position cumsum is fused too: no [T, k, E] one-hot in HBM
    position = kernel_ops.topk_positions_op(idx, probs.shape[-1],
                                            use_pallas=True)
    return gating_from_topk(idx, gate_w, probs, cap, aux_loss_weight,
                            position=position)
