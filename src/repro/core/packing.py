"""Lina §4.2 expert packing: choose experts-per-device (powers of two) so the
expert-FFN micro-op time matches the a2a micro-op time, maximizing pipeline
efficiency (paper Table 3: 33% -> 86%).

On TPU the decision is made from the analytic v5e model at compile time (the
paper measures 10 steps then repacks every 4; our Trainer re-evaluates from
its measured step stats the same way, but the *initial* choice already comes
from the model below, which the dry-run exercises).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import HardwareConfig, V5E


@dataclass(frozen=True)
class PackingDecision:
    experts_per_device: int
    ffn_us: float          # one FFN micro-op, per packed device
    a2a_us: float          # one a2a micro-op
    pipeline_efficiency: float


def ffn_microop_time(tokens: int, d_model: int, d_ff: int, ffn_mult: int,
                     hw: HardwareConfig = V5E) -> float:
    """us to run the expert FFN on `tokens` tokens (dense GEMM, MXU-bound)."""
    flops = 2 * tokens * d_model * d_ff * ffn_mult
    return flops / (hw.peak_flops * hw.sim_efficiency) * 1e6


def a2a_microop_time(tokens: int, d_model: int, ep: int, bytes_per: int = 2,
                     hw: HardwareConfig = V5E) -> float:
    """us for the dispatch a2a micro-op on a 2D-torus ICI.

    Each device sends (ep-1)/ep of its buffer; bisection-limited cost on a
    ring/torus ~ bytes * (ep-1)/ep / (links*bw)."""
    b = tokens * d_model * bytes_per
    eff = b * (ep - 1) / max(ep, 1)
    return eff / (hw.ici_links * hw.ici_bw) * 1e6


def choose_packing(tokens_per_microop: int, d_model: int, d_ff: int,
                   n_experts: int, ep: int, ffn_mult: int = 3,
                   max_pack: int = 8, hw: HardwareConfig = V5E
                   ) -> PackingDecision:
    """Paper's policy: start at 1 expert/device, double until FFN micro-op
    time exceeds the a2a micro-op time (then the pipeline is compute-bound
    and bandwidth is fully hidden)."""
    def ep_of(pack: int) -> int:
        return max(n_experts // pack, 1)

    def times(pack: int):
        # packing multiplies each device's expert tokens by `pack` and
        # shrinks the EP group (fewer a2a peers; at ep=1 a2a vanishes)
        f = ffn_microop_time(tokens_per_microop * pack, d_model, d_ff,
                             ffn_mult, hw=hw)
        a = a2a_microop_time(tokens_per_microop * pack, d_model, ep_of(pack),
                             hw=hw)
        return f, a

    pack = 1
    ffn, a2a = times(pack)
    while pack * 2 <= max_pack and ep_of(pack) > 1:
        # paper §4.2: double experts-per-device until FFN exceeds the a2a
        # micro-op (the doubling that crosses over is applied — that is what
        # hides the transfer behind compute)
        pack *= 2
        ffn, a2a = times(pack)
        if ffn > a2a:
            break
    eff = min(ffn / a2a, 1.0) if a2a > 0 else 1.0
    return PackingDecision(pack, ffn, a2a, eff)
