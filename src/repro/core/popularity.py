"""Lina §5.2: token-level expert-selection patterns -> expert popularity
estimation ahead of the gating network.

The paper profiles, per *sample path* j (the sequence of experts a token
selected in layers i-l..i), the next-layer selection distribution Ψ_j^{i+1},
then at inference estimates layer i+1's popularity from each token's path
(Eq. 1).  We store Ψ as fixed-size hashed-path tables (exact when E^l fits
the bucket count; graceful collision degradation otherwise) instead of the
paper's per-device ``unordered_map`` — bounded memory, jit-friendly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rolling_path_id(path_id: jax.Array, expert: jax.Array, n_experts: int,
                    path_len: int, n_buckets: int) -> jax.Array:
    """Update the rolling path hash with the expert chosen at this layer.

    path_id' = (path_id * E + e) mod B.  With B >= E^l this is an exact
    encoding of the last-l path (the modulus only folds older history).
    """
    return (path_id * n_experts + expert) % n_buckets


def exact_buckets(n_experts: int, path_len: int, cap: int = 1 << 16) -> int:
    """Bucket count: exact path space if it fits, else capped."""
    return int(min(n_experts ** path_len, cap))


@dataclass
class PathProfile:
    """Profiled Ψ tables: counts[layer, bucket, expert]."""

    n_layers: int
    n_experts: int
    path_len: int = 3
    n_buckets: int = 0
    counts: np.ndarray = field(default=None)  # [L, B, E] float32

    def __post_init__(self):
        if not self.n_buckets:
            self.n_buckets = exact_buckets(self.n_experts, self.path_len)
        if self.counts is None:
            self.counts = np.zeros(
                (self.n_layers, self.n_buckets, self.n_experts), np.float32)

    # -- profiling stage (run while/after training, §5.2) ------------------
    def update(self, layer: int, path_ids: np.ndarray, experts: np.ndarray):
        """Accumulate: tokens with path ``path_ids`` chose ``experts`` (top-1)
        at ``layer``.  path_ids/experts: [T] int."""
        np.add.at(self.counts[layer], (np.asarray(path_ids),
                                       np.asarray(experts)), 1.0)

    def profile_batch(self, expert_choices: np.ndarray):
        """expert_choices: [n_layers, T] top-1 expert per token per layer.
        Replays the rolling hash exactly as inference will."""
        n_layers, t = expert_choices.shape
        path = np.zeros((t,), np.int64)
        for i in range(n_layers):
            if i >= self.path_len:   # need l layers of history (paper: start
                self.update(i, path, expert_choices[i])   # from l-th layer)
            path = (path * self.n_experts + expert_choices[i]) % self.n_buckets

    # -- inference stage ----------------------------------------------------
    smoothing: float = 4.0

    def distribution(self, layer: int, path_ids) -> np.ndarray:
        """Ψ lookup: [T] path ids -> [T, E] next-layer distributions.

        Add-α smoothing toward the layer marginal: sparsely-observed paths
        interpolate to the marginal instead of over-trusting a handful of
        counts (longer paths => exponentially more buckets; without this the
        paper's 'longer path = better' trend inverts at small profile sizes)."""
        c = self.counts[layer]                              # [B, E]
        rows = c[np.asarray(path_ids)]                      # [T, E]
        row_tot = rows.sum(-1, keepdims=True)
        marginal = c.sum(0)
        marg_tot = marginal.sum()
        if marg_tot == 0:
            marginal = np.full((self.n_experts,), 1.0 / self.n_experts)
        else:
            marginal = marginal / marg_tot
        a = self.smoothing
        out = (rows + a * marginal[None, :]) / (row_tot + a)
        return out.astype(np.float32)

    def estimate_popularity(self, layer: int, path_ids) -> np.ndarray:
        """Eq. 1 aggregation: mean over tokens of per-path top-k-masked
        distributions -> [E] popularity (sums to ~1)."""
        dist = self.distribution(layer, path_ids)           # [T, E]
        pop = dist.mean(0)
        s = pop.sum()
        return pop / s if s > 0 else np.full((self.n_experts,),
                                             1.0 / self.n_experts)

    def save(self, path: str):
        np.savez_compressed(path, counts=self.counts,
                            meta=np.array([self.n_layers, self.n_experts,
                                           self.path_len, self.n_buckets]))

    @classmethod
    def load(cls, path: str) -> "PathProfile":
        z = np.load(path)
        l, e, pl, b = [int(v) for v in z["meta"]]
        return cls(n_layers=l, n_experts=e, path_len=pl, n_buckets=b,
                   counts=z["counts"])


def top2k_sets_match(est_pop: np.ndarray, actual_pop: np.ndarray,
                     k: int) -> bool:
    """The §5.2 top-2k check, the repo's single implementation: True iff the
    top-2k estimated experts equal the top-2k actual experts (as *sets*;
    'comparing the overall top-2k experts').  Shared by the phase-2
    fine-tune trigger (``placement.needs_finetune``), the accuracy metric,
    and plan-cache invalidation."""
    kk = min(2 * k, est_pop.shape[-1])
    est = set(np.argsort(-est_pop)[:kk].tolist())
    act = set(np.argsort(-actual_pop)[:kk].tolist())
    return est == act


def estimation_accuracy(est_pop: np.ndarray, actual_pop: np.ndarray,
                        k: int) -> bool:
    """Accuracy metric (Fig. 19 / Table 5): alias of the §5.2 check."""
    return top2k_sets_match(est_pop, actual_pop, k)
