"""Canonical mesh-axis names — the single source of truth.

Every collective, PartitionSpec and mesh constructor in this repo names its
axes through these constants; ``repro.analysis.collectives`` lints the tree
and flags raw string literals in axis positions (``axis-literal``) as well
as axis names outside this module's vocabulary (``unbound-axis``), so a
typo'd ``psum`` axis is a CI failure instead of a runtime shard_map error.

Axis roles (see DESIGN / ROADMAP):
  POD    outer data-parallel axis across pods (multi-pod meshes only)
  DATA   data-parallel / FSDP axis within a pod
  MODEL  expert-parallel axis (the MoE a2a runs here) + tensor parallel
  TP     expert-slicing tensor-parallel split of MODEL (archs whose expert
         count does not fill the 16-way model axis)
"""
from __future__ import annotations

POD = "pod"
DATA = "data"
MODEL = "model"
TP = "tp"

# the full canonical vocabulary, in mesh-major order
MESH_AXES = (POD, DATA, MODEL, TP)

# role aliases used across core/optim/launch
EP_AXIS = MODEL            # expert-parallel: dispatch/combine a2a axis
DP_AXES = (POD, DATA)      # data-parallel axes (gradient reduction)
MP_AXES = (MODEL, TP)      # model-parallel axes (weight sharding)


def axis_sizes(mesh) -> dict:
    """{axis name: size} for ``mesh`` (empty for None)."""
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes present on ``mesh`` (() for None)."""
    if mesh is None:
        return ()
    return DP_AXES if POD in mesh.axis_names else (DATA,)


def mp_axes(mesh) -> tuple:
    """The model/tensor-parallel axes present on ``mesh``."""
    if mesh is None:
        return (MODEL,)
    return MP_AXES if TP in mesh.axis_names else (MODEL,)
