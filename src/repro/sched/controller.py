"""Telemetry-driven expert autoscaling: the actuation half of the loop.

Every ``interval`` engine steps the controller recomputes, per MoE layer, a
candidate ``PlacementPlan`` from the telemetry bus:

  replica targets   drift-scaled water-filling of the slot budget
                    (``replica_targets``): ``fill + headroom * drift`` of
                    the spare slots, apportioned proportionally to the
                    EWMA popularity — where Eq. 1 sized replicas against
                    N devices under the fixed ``max_pack`` cap, the
                    controller scales the budget itself, so a fast-moving
                    hot set keeps spare replicas warm;
  placement         ``core.placement.plan_from_replicas`` — greedy
                    least-loaded placement that spreads one expert's
                    replicas across devices (the §5 transfer-balance
                    objective) with a fixed replica-table width so swaps
                    never change dispatch shapes;
  swap decision     hysteresis: the candidate replaces the live plan only
                    when the §5 objective (max per-device token share,
                    ``transfer_balance_cost``) improves by more than
                    ``hysteresis`` relative PLUS the modeled migration cost
                    (``migration_slots`` — expert weight stacks devices
                    would have to fetch, weighted by ``migration_weight``).
                    A per-layer ``min_swap_interval`` additionally spaces
                    swaps out.  Both bound plan churn.

``AdaptiveScheduler`` packages bus + controller + server: the engine calls
``after_step`` between micro-batches, and accepted plans are published into
the server (``MoEServer.publish_plans``), replacing the static per-batch
planner for those layers.  In-flight decode state is untouched by a swap —
plans move experts, not math.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.placement import (PlacementPlan, migration_slots,
                                  plan_from_replicas, shed_to_budget,
                                  transfer_balance_cost)
from repro.sched.telemetry import TelemetryBus, TelemetryConfig


@dataclass(frozen=True)
class ControllerConfig:
    interval: int = 4            # engine steps between evaluations
    headroom: float = 0.2        # drift -> uniform-hedge gain
    risk: float = 1.0            # std multiplier of the popularity envelope
    fill: float = 1.0            # fraction of spare slots to use
    replica_floor: int = 0       # min replicas per expert (0 = auto)
    max_moves: int = 6           # replica adds per layer per control step
    #                              (migration throttle; 0 = unthrottled)
    hysteresis: float = 0.1      # min relative objective improvement to swap
    min_swap_interval: int = 0   # steps between swaps per layer (0: interval)
    migration_weight: float = 0.05  # objective units per migrated slot share
    max_replicas: int = 0        # per-expert replica cap (0: n_devices)
    min_observations: int = 2    # bus observations before the first plan


def replica_targets(popularity: np.ndarray, n_devices: int,
                    drift_rate: float = 0.0, headroom: float = 1.0,
                    max_replicas: int = 0, budget: int = 0,
                    fill: float = 1.0, floor: int = 0) -> np.ndarray:
    """Per-expert replica counts from observed popularity: water-filling of
    the slot budget with a drift-scaled hedge and a replica floor.

    Where Eq. 1 sizes replicas against ``n_devices`` under the fixed
    ``max_pack`` cap, the controller treats the WHOLE slot budget as the
    scaling resource: ``fill`` of the spare slots (beyond the floor) are
    apportioned proportionally to popularity (floor + largest-remainder).
    Two robustness levers cover what a time-averaged basis cannot see:

      - ``floor`` replicas per expert (default: 2 when the budget leaves
        at least ~half the spare slots free afterwards, else 1) bound the
        straggler cost of an expert that is cold on average but spikes hot
        in a single micro-batch — per-batch sampling noise;
      - the apportionment basis is blended toward uniform by
        ``headroom * drift_rate`` — the drift-scaled headroom: on a
        fast-moving layer the incoming hot experts (which the EWMA lags)
        hold spare replicas *before* their traffic lands.

    Monotone in popularity (pop_i >= pop_j implies r_i >= r_j): the blend
    and quotas are monotone maps, largest-remainder apportionment serves
    the larger quota first among equal floors, and budget shedding always
    decrements a least-popular expert among the widest.
    """
    pop = np.asarray(popularity, np.float64)
    pop = pop / max(pop.sum(), 1e-12)
    e = pop.shape[0]
    max_replicas = max_replicas or n_devices
    budget = budget or n_devices
    assert budget >= e, "budget must host every expert once"
    if not floor:
        floor = 2 if budget >= 2 * e + (budget - e) // 2 else 1
    floor = max(1, min(floor, budget // e))
    lam = float(np.clip(headroom * np.clip(drift_rate, 0.0, 1.0), 0.0, 0.9))
    pop_h = (1.0 - lam) * pop + lam / e
    target = floor * e + int(round((budget - floor * e) *
                                   float(np.clip(fill, 0.0, 1.0))))
    quota = pop_h * target
    r = np.maximum(floor, np.floor(quota).astype(np.int64))
    r = np.minimum(r, min(max_replicas, n_devices))
    spare = target - int(r.sum())
    if spare > 0:
        # remainder RELATIVE TO the floored/clipped count: an expert the
        # floor already lifted above its quota has a negative remainder,
        # so it cannot outrank a more popular expert at the same count
        # (keeps the apportionment monotone in popularity)
        rem = quota - r
        for ex in np.lexsort((-pop_h, -rem)):     # largest remainder first
            if spare <= 0:
                break
            if r[ex] < min(max_replicas, n_devices):
                r[ex] += 1
                spare -= 1
    return shed_to_budget(r, pop_h, budget)


class AutoscaleController:
    """Recomputes per-layer plans from telemetry; hysteresis bounds churn."""

    def __init__(self, n_devices: int, max_pack: int = 4,
                 cfg: Optional[ControllerConfig] = None):
        self.n_devices = n_devices
        self.max_pack = max_pack
        self.cfg = cfg or ControllerConfig()
        self.dead_devices: set = set()   # masked out of every candidate
        self.plans: Dict[int, PlacementPlan] = {}     # live published plans
        self._last_swap: Dict[int, int] = {}
        self.evaluations = 0
        self.swaps = 0          # re-plans of a live layer (the churn metric)
        self.bootstraps = 0     # first publish per layer (not churn)
        self.steps_seen = 0
        self.migrated_slots = 0      # cumulative expert stacks moved (swaps)
        self.pending_migration = 0   # slots moved since last pop_migration()

    def pop_migration(self) -> int:
        """Expert weight stacks moved by swaps since the last call — the
        benchmark's service model charges their transfer time to the step
        that performs the migration."""
        m = self.pending_migration
        self.pending_migration = 0
        return m

    # --- candidate construction --------------------------------------------
    def candidate(self, popularity: np.ndarray, drift_rate: float,
                  prev: Optional[PlacementPlan] = None) -> PlacementPlan:
        live = self.n_devices - len(self.dead_devices)
        r = replica_targets(popularity, live, drift_rate,
                            headroom=self.cfg.headroom,
                            fill=self.cfg.fill,
                            floor=self.cfg.replica_floor,
                            max_replicas=self.cfg.max_replicas,
                            budget=live * self.max_pack)
        if prev is not None and self.cfg.max_moves:
            r = self._throttle(r, prev, popularity)
        return plan_from_replicas(popularity, r, self.n_devices,
                                  max_pack=self.max_pack,
                                  rep_width=self.n_devices, prev=prev,
                                  dead_devices=self.dead_devices)

    def _throttle(self, target: np.ndarray, prev: PlacementPlan,
                  pop: np.ndarray) -> np.ndarray:
        """Migration throttle: move replica counts at most ``max_moves``
        additions toward the target per control step (weights are copied in
        the background in a real deployment — §6.2's weight swap — so each
        step's swap stays a bounded, absorbable cost instead of a storm).
        Additions are funded by shedding from the most over-target experts
        (coldest first), largest-deficit hottest experts served first."""
        cur = np.asarray(prev.n_replicas, np.int64).copy()
        deficit = target - cur
        adds = self.cfg.max_moves
        order = np.lexsort((-pop, -deficit))      # biggest deficit, hottest
        for ex in order:
            if adds <= 0 or deficit[ex] <= 0:
                break
            grant = int(min(deficit[ex], adds))
            cur[ex] += grant
            adds -= grant
        budget = (self.n_devices - len(self.dead_devices)) * self.max_pack
        while cur.sum() > budget:
            over = cur - target
            mx = over.max()
            if mx <= 0:
                cand = np.flatnonzero(cur == cur.max())
            else:
                cand = np.flatnonzero(over == mx)
            cur[cand[np.argmin(pop[cand])]] -= 1
        return np.maximum(cur, 1)

    # --- the control step ---------------------------------------------------
    def step(self, bus: TelemetryBus, step_idx: int
             ) -> Optional[Dict[int, PlacementPlan]]:
        """Evaluate every observed layer; returns the plans that changed
        (to publish), or None when nothing swapped this step."""
        cfg = self.cfg
        self.steps_seen = step_idx
        # bootstrap runs as soon as a layer has telemetry (every pre-plan
        # step is a step the per-batch planner still owns); steady-state
        # re-evaluation runs at the interval cadence
        unplanned = any(li not in self.plans for li in bus.layers())
        if step_idx % max(cfg.interval, 1) and not unplanned:
            return None
        min_gap = cfg.min_swap_interval or cfg.interval
        total_slots = self.n_devices * self.max_pack
        changed: Dict[int, PlacementPlan] = {}
        for li in bus.layers():
            lt = bus.layer(li)
            if lt is None or lt.steps < cfg.min_observations:
                continue
            if step_idx - self._last_swap.get(li, -min_gap) < min_gap:
                continue
            # plan against the envelope (mean + risk*std of per-batch
            # shares): replica width must cover what an expert can draw in
            # one micro-batch, not just its time-averaged share
            pop = bus.popularity_envelope(li, self.cfg.risk)
            if pop is None:
                continue
            self.evaluations += 1
            cur = self.plans.get(li)
            cand = self.candidate(pop, bus.drift_rate(li), prev=cur)
            if cur is not None:
                # both plans are scored on the CURRENT EWMA: the live plan
                # was fitted to an older average, so its score decays as
                # the distribution moves, while single-batch spikes (which
                # the replica floor already covers) cannot thrash the gate
                j_cur = transfer_balance_cost(cur, pop)
                j_new = transfer_balance_cost(cand, pop)
                mslots = migration_slots(cur, cand)
                gain = j_cur - j_new
                if gain <= cfg.hysteresis * j_cur + \
                        cfg.migration_weight * (mslots / total_slots):
                    continue                      # not worth the churn
                self.swaps += 1
                self.migrated_slots += mslots
                self.pending_migration += mslots
            else:
                self.bootstraps += 1
            self.plans[li] = cand
            self._last_swap[li] = step_idx
            changed[li] = cand
        return changed or None

    @property
    def churn_per_100_steps(self) -> float:
        """Plan swaps per 100 engine steps — the churn metric hysteresis
        bounds (layer-swaps, summed over layers)."""
        return 100.0 * self.swaps / max(self.steps_seen, 1)


class AdaptiveScheduler:
    """Bus + controller + server, packaged for the serving engine.

    The engine calls ``after_step(stats, n_tokens)`` between micro-batches;
    telemetry is recorded, the controller runs at its cadence, and accepted
    plans are published into the server.  Construction wires the modeled
    a2a byte size from the server's model config.
    """

    def __init__(self, server, ccfg: Optional[ControllerConfig] = None,
                 tcfg: Optional[TelemetryConfig] = None):
        if tcfg is None:
            itemsize = np.dtype(server.cfg.dtype).itemsize
            tcfg = TelemetryConfig(
                top_k=server.scfg.top_k,
                bytes_per_token=float(server.cfg.d_model * itemsize))
        self.server = server
        # the operator view rides the server's shared obs registry; the bus
        # itself stays the policy view the controller plans from
        obs = getattr(server, "obs", None)
        self.bus = TelemetryBus(tcfg,
                                metrics=None if obs is None else obs.metrics)
        self.controller = AutoscaleController(server.n_dev,
                                              max_pack=server.scfg.max_pack,
                                              cfg=ccfg)
        self.step_idx = 0

    def after_step(self, stats: List, n_tokens: int) -> bool:
        """Returns True when a plan swap was published this step.

        The control step is exception-isolated (always-on degradation): a
        crashing controller leaves the last published plans serving and
        lands on the bus's error ledger instead of taking the serving loop
        down with it."""
        self.step_idx += 1
        self.bus.observe_step(stats, n_tokens)
        cache = getattr(self.server, "plan_cache", None)
        if cache is not None:
            self.bus.observe_cache(cache.stats)
        try:
            plans = self.controller.step(self.bus, self.step_idx)
        except Exception:
            self.bus.record_error("controller_step")
            plans = None
        if plans:
            self.server.publish_plans(plans)
            if self.bus.metrics is not None:
                self.bus.metrics.counter(
                    "sched_plan_swaps_total").inc(len(plans))
            return True
        return False

    # --- graceful degradation (repro.resilience) ---------------------------
    def fail_devices(self, devices) -> None:
        """Propagate a device failure through the whole control loop: the
        controller masks the devices out of every future candidate, its
        live plans touching them are dropped (an unplanned layer triggers
        an immediate re-bootstrap at the next step, bypassing the interval
        and swap-gap gating), and the server re-routes around them now."""
        devs = {int(d) for d in devices}
        self.controller.dead_devices |= devs
        for li, plan in list(self.controller.plans.items()):
            dead_slots = plan.slot_expert[sorted(
                d for d in devs if d < plan.n_devices)]
            if (np.asarray(dead_slots) >= 0).any():
                del self.controller.plans[li]
                self.controller._last_swap.pop(li, None)
        self.server.fail_devices(devs)

    def revive_devices(self, devices) -> None:
        devs = {int(d) for d in devices}
        self.controller.dead_devices -= devs
        self.server.revive_devices(devs)

    @property
    def churn_per_100_steps(self) -> float:
        return self.controller.churn_per_100_steps

    def report(self) -> dict:
        return {
            "steps": self.step_idx,
            "swaps": self.controller.swaps,
            "bootstraps": self.controller.bootstraps,
            "evaluations": self.controller.evaluations,
            "churn_per_100_steps": self.churn_per_100_steps,
            "telemetry": self.bus.snapshot(),
        }
