"""Trace-driven workload engine: seeded, replayable request streams whose
expert popularity MOVES — the traffic the static benchmark can't express.

The repo's smoke models route by token content (random embeddings + a
skewed router), so *which vocabulary a request draws from* determines which
experts get hot.  Drift is modeled the way content popularity actually
moves — as a TOPIC MIXTURE with slowly-varying weights: the vocabulary is
split into ``topics`` disjoint token pools, each with a fixed internal Zipf
ranking (a topic's #1 token stays its #1 token), and request tokens are
drawn from the mixture whose weights rotate over ``drift_period``.  The
expert-popularity distribution therefore drifts smoothly and *learnably*
(yesterday's hot topic fades while the next rises), rather than re-rolling
per request — popularity noise at request granularity is white noise no
scheduler can beat, and models nothing real.

  stationary      fixed mixture weights, Poisson arrivals — the PR-1
                  regime;
  drifting_zipf   the mixture weights rotate continuously (one full cycle
                  over the topics per ``drift_period`` virtual seconds), so
                  the hot-expert set migrates under the server;
  flash_crowd     stationary background, then a burst window where the
                  arrival rate multiplies and every request draws from a
                  tiny far-away pool — an abrupt popularity flip plus a
                  load spike;
  diurnal         the arrival rate swings sinusoidally over the trace while
                  the mixture rotates slowly — the daily tide.

``generate_trace(spec, vocab_size)`` is a pure function of its arguments:
the same seed replays the identical (tokens, arrival) stream, so controller
experiments are reproducible end-to-end.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

KINDS = ("stationary", "drifting_zipf", "flash_crowd", "diurnal")


@dataclass(frozen=True)
class TraceSpec:
    kind: str = "drifting_zipf"
    n_requests: int = 64
    seq: int = 32
    rate_hz: float = 20.0        # mean arrival rate (requests / virtual s)
    seed: int = 0
    zipf_a: float = 1.3          # skew of token ranks within a topic pool
    pool: int = 16               # tokens per topic pool
    topics: int = 4              # topic pools in the mixture
    kappa: float = 3.0           # mixture sharpness (higher = one topic hot)
    drift_period: float = 2.0    # virtual s per full mixture rotation
    flash_start: float = 0.4     # burst start, fraction of nominal duration
    flash_dur: float = 0.25      # burst length, fraction of nominal duration
    flash_mult: float = 4.0      # arrival-rate multiplier inside the burst
    flash_pool: int = 4          # burst pool size (tiny => sharp flip)
    diurnal_amp: float = 0.8     # rate swing amplitude, fraction of rate_hz

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    @property
    def duration(self) -> float:
        """Nominal trace duration in virtual seconds."""
        return self.n_requests / self.rate_hz


def _in_flash(spec: TraceSpec, t: float) -> bool:
    d = spec.duration
    return spec.kind == "flash_crowd" and \
        spec.flash_start * d <= t < (spec.flash_start + spec.flash_dur) * d


def _rate(spec: TraceSpec, t: float) -> float:
    if spec.kind == "flash_crowd":
        return spec.rate_hz * (spec.flash_mult if _in_flash(spec, t) else 1.0)
    if spec.kind == "diurnal":
        return spec.rate_hz * (1.0 + spec.diurnal_amp *
                               np.sin(2.0 * np.pi * t / spec.duration))
    return spec.rate_hz


def _mixture_weights(spec: TraceSpec, t: float) -> np.ndarray:
    """Topic weights at virtual time ``t``: a von-Mises-style bump rotating
    over the topic ring; ``kappa`` sets how dominant the hot topic is."""
    k = np.arange(spec.topics)
    if spec.kind == "drifting_zipf":
        phase = t / spec.drift_period
    elif spec.kind == "diurnal":
        phase = t / (2.0 * spec.drift_period)     # slower tide
    else:
        phase = 0.0
    w = np.exp(spec.kappa * np.cos(2.0 * np.pi * (phase - k / spec.topics)))
    return w / w.sum()


def _token_probs(spec: TraceSpec, t: float, vocab: int,
                 perm: np.ndarray):
    """(candidate token ids, per-token probabilities) at time ``t``."""
    if _in_flash(spec, t):
        fp = min(spec.flash_pool, vocab)
        return perm[(vocab // 2 + np.arange(fp)) % vocab], \
            np.full((fp,), 1.0 / fp)
    pool = min(spec.pool, max(1, vocab // max(spec.topics, 1)))
    ranks = np.arange(1, pool + 1, dtype=np.float64) ** -spec.zipf_a
    ranks /= ranks.sum()
    weights = _mixture_weights(spec, t)
    ids = np.concatenate([perm[(k * pool + np.arange(pool)) % vocab]
                          for k in range(spec.topics)])
    p = np.concatenate([w * ranks for w in weights])
    return ids, p / p.sum()


def generate_trace(spec: TraceSpec, vocab_size: int
                   ) -> List[Tuple[np.ndarray, float]]:
    """Seeded open-loop trace: [(tokens [seq] int64, arrival_s)], sorted by
    arrival.  Feed straight into ``runtime.engine.simulate``."""
    rng = np.random.RandomState(spec.seed)
    perm = rng.permutation(vocab_size)
    trace: List[Tuple[np.ndarray, float]] = []
    t = 0.0
    for _ in range(spec.n_requests):
        t += rng.exponential(1.0 / max(_rate(spec, t), 1e-9))
        ids, p = _token_probs(spec, t, vocab_size, perm)
        tokens = ids[rng.choice(ids.shape[0], spec.seq, p=p)]
        trace.append((tokens.astype(np.int64), t))
    return trace


# Named scenarios the serve driver and the autoscale benchmark share; the
# two ``drift*`` entries are the "at least two drifting-popularity traces"
# the acceptance bar names (the flash crowd drifts abruptly, the zipf
# window continuously).
SCENARIOS = {
    "stationary": TraceSpec(kind="stationary"),
    "drift": TraceSpec(kind="drifting_zipf", drift_period=2.0),
    "drift_fast": TraceSpec(kind="drifting_zipf", drift_period=0.8),
    "flash": TraceSpec(kind="flash_crowd"),
    "diurnal": TraceSpec(kind="diurnal"),
}


def get_spec(name: str, **overrides) -> TraceSpec:
    """A named scenario's spec with field overrides applied (seed,
    n_requests, seq, rate_hz, ...) — the one way drivers instantiate
    scenarios, so override handling cannot diverge between them."""
    spec = SCENARIOS[name]
    return dataclasses.replace(spec, **overrides) if overrides else spec


def get_trace(name: str, vocab_size: int, **overrides
              ) -> List[Tuple[np.ndarray, float]]:
    """``generate_trace(get_spec(name, **overrides), vocab_size)``."""
    return generate_trace(get_spec(name, **overrides), vocab_size)
