"""Adaptive resource scheduling for serving (closes the §5 loop).

The static stack plans from a per-batch popularity estimate under a fixed
``max_pack`` replica cap.  This package turns that into a control loop:

  ``telemetry``  — per-layer metrics bus the serving path feeds every step
                   (EWMA expert popularity, drift rate, PlanCache hit /
                   invalidation rates, per-device load and modeled a2a
                   bytes);
  ``controller`` — telemetry-driven autoscaling of per-layer replica
                   counts and expert→device placement, with hysteresis and
                   a migration-cost model bounding plan churn;
  ``workloads``  — seeded, replayable request-trace generator (drifting
                   Zipf skew, flash crowds, diurnal shifts) that exercises
                   the controller under traffic the static benchmark
                   cannot express.
"""
from repro.sched.controller import (AdaptiveScheduler, AutoscaleController,
                                    ControllerConfig, replica_targets)
from repro.sched.telemetry import LayerTelemetry, TelemetryBus, TelemetryConfig
from repro.sched.workloads import (SCENARIOS, TraceSpec, generate_trace,
                                   get_spec, get_trace)

__all__ = [
    "AdaptiveScheduler", "AutoscaleController", "ControllerConfig",
    "replica_targets", "LayerTelemetry", "TelemetryBus", "TelemetryConfig",
    "SCENARIOS", "TraceSpec", "generate_trace", "get_spec", "get_trace",
]
