"""Per-layer serving telemetry: the observation half of the control loop.

The engine feeds the bus once per micro-batch with the ``LayerStats`` the
server produced (realized expert popularity, per-device token shares,
fine-tune / plan-reuse flags) plus the token count served; the bus keeps
EWMAs so the controller sees a smoothed, recency-weighted view:

  popularity   EWMA of the realized per-layer expert histogram — what the
               controller plans from (not the per-batch estimate, which
               autoscaled serving no longer blocks on);
  drift rate   EWMA of the §5.2 top-2k-set-changed indicator between
               consecutive observations — how fast this layer's hot set is
               moving, which scales the controller's replica headroom;
  device load  EWMA of max/mean per-device token share under the active
               plan, and the modeled per-device a2a bytes it implies;
  plan cache   hit / miss / drift-invalidation *rates* derived from the
               PlanCache counter deltas between observations.

Everything is plain numpy on the host — the bus sits next to the planner
('scheduler on device 0', §6.2), never inside jit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.popularity import top2k_sets_match


@dataclass(frozen=True)
class TelemetryConfig:
    alpha: float = 0.25          # EWMA weight of the newest popularity obs
    slow_alpha: float = 0.0625   # slow-EWMA weight (drift reference)
    drift_alpha: float = 0.125   # EWMA weight of the drift indicator
    top_k: int = 1               # top-2k set size for the drift indicator
    bytes_per_token: float = 0.0  # d_model * itemsize; 0 = bytes not modeled
    obs_tokens_ref: float = 64.0  # obs weight saturates at this token count
    #                               (a 2-token decode batch moves the EWMA
    #                               1/32 as much as a full prefill; 0 = off)


@dataclass
class LayerTelemetry:
    """EWMA state for one MoE layer."""
    n_experts: int
    popularity: Optional[np.ndarray] = None   # [E] EWMA, sums to ~1
    popularity_var: Optional[np.ndarray] = None   # [E] EWMA batch variance
    popularity_slow: Optional[np.ndarray] = None  # [E] slow EWMA (reference)
    drift_rate: float = 0.0                   # in [0, 1]
    load_max: float = 0.0                     # EWMA max device token share
    load_mean: float = 0.0                    # EWMA mean device token share
    tokens: float = 0.0                       # EWMA tokens per observation
    rep_max: float = 0.0                      # EWMA max per-replica tokens
    rep_mean: float = 0.0                     # EWMA mean per-replica tokens
    steps: int = 0
    finetunes: int = 0
    reuses: int = 0
    _last_pop: Optional[np.ndarray] = None

    @property
    def imbalance(self) -> float:
        """max/mean device token share — 1.0 is perfectly balanced."""
        return self.load_max / self.load_mean if self.load_mean > 0 else 0.0

    @property
    def replica_imbalance(self) -> float:
        """max/mean realized tokens per placement slot — how evenly the
        weighted router spreads an expert's load over its replicas (the
        quantity Lina's weighted scheduling targets; 1.0 = perfectly even,
        0.0 = not yet observed)."""
        return self.rep_max / self.rep_mean if self.rep_mean > 0 else 0.0

    def a2a_bytes(self, bytes_per_token: float) -> float:
        """Modeled bytes the most-loaded device's link carries per step
        (dispatch + combine) under the observed load."""
        return 2.0 * self.tokens * self.load_max * bytes_per_token


class TelemetryBus:
    """Collects per-layer serving metrics; the controller reads snapshots.

    The bus remains the scheduling-POLICY view (EWMAs the controller plans
    from).  Pass a ``repro.obs.MetricsRegistry`` as ``metrics`` to also
    publish the operator view: drift/imbalance gauges per layer, cache-rate
    gauges, and the error ledger as labeled counters."""

    def __init__(self, cfg: Optional[TelemetryConfig] = None, metrics=None):
        self.cfg = cfg or TelemetryConfig()
        self.metrics = metrics
        self._layers: Dict[int, LayerTelemetry] = {}
        self._cache_last = (0, 0, 0)      # (hits, misses, invalidations)
        self.cache_rates = {"hit": 0.0, "miss": 0.0, "invalidation": 0.0}
        self.steps = 0
        # error ledger (repro.resilience): rejected-telemetry and isolated
        # control-loop failures land here instead of crashing the loop
        self.errors: Dict[str, int] = {}

    def record_error(self, kind: str) -> None:
        """Count a named control-plane error (e.g. ``controller_step``,
        ``telemetry_rejected``) — the observability half of exception
        isolation: degraded, but never silent."""
        self.errors[kind] = self.errors.get(kind, 0) + 1
        if self.metrics is not None:
            self.metrics.counter("sched_bus_errors_total", kind=kind).inc()

    @staticmethod
    def _valid_obs(pop: np.ndarray, load: np.ndarray) -> bool:
        """A corrupted snapshot (NaN/inf/negative histogram or device load)
        must not poison the EWMAs the controller plans from."""
        return bool(np.isfinite(pop).all() and (pop >= 0).all()
                    and np.isfinite(load).all() and (load >= 0).all())

    # --- feeding ------------------------------------------------------------
    def observe_step(self, stats: List, n_tokens: int) -> None:
        """One engine micro-batch: ``stats`` is the server's LayerStats list
        (may span multiple forwards), ``n_tokens`` the valid tokens served."""
        da = self.cfg.drift_alpha
        self.steps += 1
        for s in stats:
            lt = self._layers.get(s.layer)
            if lt is None:
                lt = self._layers[s.layer] = LayerTelemetry(
                    n_experts=int(np.asarray(s.actual_pop).shape[0]))
            pop = np.asarray(s.actual_pop, np.float64)
            load = np.asarray(s.device_load, np.float64)
            if not self._valid_obs(pop, load):
                self.record_error("telemetry_rejected")
                continue
            tot = pop.sum()
            if tot <= 0:          # all-padding micro-batch: nothing observed
                continue
            pop = pop / tot
            toks = getattr(s, "n_tokens", 0) or n_tokens
            w = min(1.0, toks / self.cfg.obs_tokens_ref) \
                if self.cfg.obs_tokens_ref else 1.0
            a = self.cfg.alpha * w
            if lt.popularity is None:
                lt.popularity = pop.copy()
                lt.popularity_var = np.zeros_like(pop)
                lt.popularity_slow = pop.copy()
            else:
                dev = pop - lt.popularity
                lt.popularity += a * dev
                # EWMA of per-batch share variance: how far a single
                # micro-batch swings each expert from its running mean —
                # the controller plans against mean + k*std (upper
                # envelope), its safety stock for sampling spikes
                lt.popularity_var += a * (dev * dev - lt.popularity_var)
                lt.popularity_slow += self.cfg.slow_alpha * w * \
                    (pop - lt.popularity_slow)
                # drift = the fast average pulling away from the slow one —
                # robust to single-batch spikes (a tiny decode batch barely
                # moves either EWMA), unlike comparing consecutive batches
                drifted = float(not top2k_sets_match(
                    lt.popularity, lt.popularity_slow, self.cfg.top_k))
                lt.drift_rate += da * (drifted - lt.drift_rate)
            lt._last_pop = pop
            load = np.asarray(s.device_load, np.float64)
            lt.load_max += a * (float(load.max()) - lt.load_max)
            lt.load_mean += a * (float(load.mean()) - lt.load_mean)
            rep = getattr(s, "replica_load", None)
            if rep is not None:
                rep = np.asarray(rep, np.float64)
                if rep.size and rep.sum() > 0:
                    lt.rep_max += a * (float(rep.max()) - lt.rep_max)
                    lt.rep_mean += a * (float(rep.mean()) - lt.rep_mean)
            lt.tokens += a * (float(toks) - lt.tokens)
            lt.steps += 1
            lt.finetunes += int(s.finetuned)
            lt.reuses += int(s.plan_reused)
            if self.metrics is not None:
                g = self.metrics.gauge
                lab = str(int(s.layer))
                g("sched_drift_rate", layer=lab).set(lt.drift_rate)
                g("sched_device_imbalance", layer=lab).set(lt.imbalance)
                g("sched_replica_imbalance",
                  layer=lab).set(lt.replica_imbalance)

    def observe_cache(self, stats) -> None:
        """Fold a PlanCacheStats snapshot into hit/miss/invalidation rates
        (EWMA over the deltas since the previous snapshot)."""
        if stats is None:
            return
        cur = (stats.hits, stats.misses, stats.invalidations)
        d = [max(0, c - l) for c, l in zip(cur, self._cache_last)]
        self._cache_last = cur
        total = d[0] + d[1]
        if total:
            a = self.cfg.alpha
            for key, val in zip(("hit", "miss", "invalidation"),
                                (d[0] / total, d[1] / total, d[2] / total)):
                self.cache_rates[key] += a * (val - self.cache_rates[key])
        if self.metrics is not None:
            for key, val in self.cache_rates.items():
                self.metrics.gauge("sched_plan_cache_rate",
                                   outcome=key).set(val)

    # --- reading ------------------------------------------------------------
    def layers(self) -> List[int]:
        return sorted(self._layers)

    def layer(self, li: int) -> Optional[LayerTelemetry]:
        return self._layers.get(li)

    def popularity(self, li: int) -> Optional[np.ndarray]:
        lt = self._layers.get(li)
        return None if lt is None or lt.popularity is None \
            else lt.popularity / max(lt.popularity.sum(), 1e-12)

    def last_popularity(self, li: int) -> Optional[np.ndarray]:
        """The most recent single-batch histogram — spiky, but it is what
        the live plan is actually serving; the controller scores plan
        staleness against it."""
        lt = self._layers.get(li)
        return None if lt is None else lt._last_pop

    def popularity_envelope(self, li: int, risk: float = 1.0
                            ) -> Optional[np.ndarray]:
        """mean + ``risk`` * std of each expert's per-batch share,
        renormalized — the upper envelope the controller sizes replicas
        against (straggler cost is a max, so width must cover what an
        expert *can* draw in one batch, not just its average)."""
        lt = self._layers.get(li)
        if lt is None or lt.popularity is None:
            return None
        env = lt.popularity + risk * np.sqrt(np.maximum(lt.popularity_var,
                                                        0.0))
        return env / max(env.sum(), 1e-12)

    def drift_rate(self, li: int) -> float:
        lt = self._layers.get(li)
        return 0.0 if lt is None else lt.drift_rate

    def snapshot(self) -> dict:
        """Host-side report (serve driver / benchmark JSON)."""
        return {
            "steps": self.steps,
            "cache_rates": dict(self.cache_rates),
            "errors": dict(self.errors),
            "layers": {
                li: {
                    "drift_rate": lt.drift_rate,
                    "imbalance": lt.imbalance,
                    "replica_imbalance": lt.replica_imbalance,
                    "tokens_ewma": lt.tokens,
                    "a2a_bytes_max": lt.a2a_bytes(self.cfg.bytes_per_token),
                    "observations": lt.steps,
                    "finetunes": lt.finetunes,
                    "plan_reuses": lt.reuses,
                } for li, lt in sorted(self._layers.items())
            },
        }
