"""Fault-tolerant checkpointing.

  * atomic: write to <dir>.tmp then rename — a killed job never leaves a
    half checkpoint that restart would read;
  * checksummed: the manifest records a CRC32 per array, verified on load,
    so a torn/bit-rotted write is detected instead of silently restored;
    ``restore_latest`` falls back to the newest step that verifies;
  * keep-last-k garbage collection;
  * layout-free storage: leaves are saved as host numpy in the LOGICAL
    (unsharded) layout plus a treedef manifest, so restore can re-shard to
    ANY mesh (elastic scaling: save on 1x8, resume on 2x4 — test-verified);
  * step indexing and 'latest' discovery for automatic restart.

At 1000+ nodes each host would write only its owned shards (the manifest
format already records per-leaf paths); on this single-host container the
gather-to-host path exercises the same interface.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np


class CorruptCheckpointError(ValueError):
    """A checkpoint failed checksum/shape verification on load."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "name", getattr(p, "key", getattr(p, "idx", p))))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save_pytree(tree, directory: str):
    """Atomic: materialize to host, write npz + manifest, rename."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = []
    for i, (key, leaf) in enumerate(flat):
        name = f"a{i}"
        arrays[name] = np.asarray(jax.device_get(leaf))
        manifest.append({"key": key, "name": name,
                         "dtype": str(arrays[name].dtype),
                         "shape": list(arrays[name].shape),
                         "crc32": _crc(arrays[name])})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)


def load_pytree(directory: str, like, shardings=None, verify: bool = True):
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (a pytree of NamedSharding) — elastic resharding happens
    here, on load, regardless of the mesh the checkpoint was written on.

    With ``verify`` (default), every array's CRC32 is checked against the
    manifest; a mismatch (torn write, bit rot) raises
    ``CorruptCheckpointError`` — which ``restore_latest`` catches to fall
    back to an older step.  Pre-checksum checkpoints (no ``crc32`` field)
    load unverified."""
    import zipfile
    try:
        z = np.load(os.path.join(directory, "arrays.npz"))
        with open(os.path.join(directory, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError, KeyError, zipfile.BadZipFile,
            json.JSONDecodeError) as e:
        raise CorruptCheckpointError(f"{directory}: unreadable ({e})") from e

    def restore_dtype(arr, want: str):
        # np.savez stores ml_dtypes (bfloat16, float8_*) as raw void bytes;
        # the manifest remembers the true dtype — reinterpret on load.
        if str(arr.dtype) != want:
            import jax.numpy as jnp
            arr = arr.view(jnp.dtype(want))
        return arr

    by_key = {}
    for m in manifest:
        try:
            raw = z[m["name"]]
        except (KeyError, ValueError, OSError, zipfile.BadZipFile) as e:
            raise CorruptCheckpointError(
                f"{directory}: missing/unreadable array {m['key']!r}") from e
        if verify and "crc32" in m and _crc(raw) != m["crc32"]:
            raise CorruptCheckpointError(
                f"{directory}: checksum mismatch on {m['key']!r}")
        by_key[m["key"]] = restore_dtype(raw, m["dtype"])
    flat, treedef = _flatten_with_paths(like)
    leaves = []
    for key, leaf in flat:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs model {leaf.shape}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
            tree, shardings)
    return tree


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self.corrupt_steps: list = []   # steps restore_latest skipped
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self):
        out = []
        for d in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.root, d,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, state: Any):
        save_pytree(state, self._dir(step))
        for old in self.steps()[: -self.keep]:
            shutil.rmtree(self._dir(old), ignore_errors=True)

    def restore(self, step: int, like: Any, shardings=None,
                verify: bool = True):
        return load_pytree(self._dir(step), like, shardings, verify=verify)

    def restore_latest(self, like: Any, shardings=None):
        """Restore the newest step that passes verification, walking past
        corrupted/torn checkpoints (recorded in ``corrupt_steps``) instead
        of crashing on them.  Returns (None, None) when nothing loads."""
        for s in reversed(self.steps()):
            try:
                return s, self.restore(s, like, shardings)
            except CorruptCheckpointError:
                self.corrupt_steps.append(s)
        return None, None
