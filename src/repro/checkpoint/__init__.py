"""Checkpoint substrate: atomic save/restore, keep-k, elastic resharding."""
from repro.checkpoint.manager import CheckpointManager, save_pytree, load_pytree
