"""GQA/MQA/MHA attention with qk-norm, QKV bias, sliding window, RoPE;
train/prefill (full-sequence) and decode (KV cache) paths.

Tensor-parallel over `model` (heads split), FSDP over the dp axes (weight
dims), expressed as weight/activation sharding constraints; the prefill path
can optionally call the Pallas flash kernel (on TPU) — CPU uses the einsum
reference, which is also the kernel oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (dense_init, rms_norm, rope, constrain,
                                 dp_axes, tp_axes)


class AttnParams(NamedTuple):
    wq: jax.Array                 # [d, H*hd]
    wk: jax.Array                 # [d, KV*hd]
    wv: jax.Array                 # [d, KV*hd]
    wo: jax.Array                 # [H*hd, d]
    bq: Optional[jax.Array]       # [H*hd] or None
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]
    q_norm: Optional[jax.Array]   # [hd] qk_norm scales
    k_norm: Optional[jax.Array]


class KVCache(NamedTuple):
    k: jax.Array                  # [B, S_max, KV, hd]
    v: jax.Array                  # [B, S_max, KV, hd]


def init_attn_params(key, d_model, n_heads, n_kv_heads, head_dim, *,
                     qkv_bias=False, qk_norm=False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    hq, hkv = n_heads * head_dim, n_kv_heads * head_dim
    z = lambda n: jnp.zeros((n,), dtype)
    return AttnParams(
        wq=dense_init(ks[0], (d_model, hq), dtype=dtype),
        wk=dense_init(ks[1], (d_model, hkv), dtype=dtype),
        wv=dense_init(ks[2], (d_model, hkv), dtype=dtype),
        wo=dense_init(ks[3], (hq, d_model), dtype=dtype),
        bq=z(hq) if qkv_bias else None,
        bk=z(hkv) if qkv_bias else None,
        bv=z(hkv) if qkv_bias else None,
        q_norm=jnp.ones((head_dim,), dtype) if qk_norm else None,
        k_norm=jnp.ones((head_dim,), dtype) if qk_norm else None,
    )


def _project_qkv(p: AttnParams, x, n_heads, n_kv_heads, head_dim, positions,
                 rope_theta, norm_eps):
    b, s, _ = x.shape
    q = x @ p.wq + (p.bq if p.bq is not None else 0.0)
    k = x @ p.wk + (p.bk if p.bk is not None else 0.0)
    v = x @ p.wv + (p.bv if p.bv is not None else 0.0)
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, norm_eps)
        k = rms_norm(k, p.k_norm, norm_eps)
    if rope_theta > 0:
        q, k = rope(q, k, positions, rope_theta)
    return q, k, v


def _sdpa(q, k, v, *, causal, window, q_offset=0):
    """Reference attention.  q: [B,Sq,H,hd], k/v: [B,Sk,KV,hd]."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    v = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits / (hd ** 0.5)
    sk = k.shape[1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


BLOCKWISE_THRESHOLD = 2048   # S beyond which the O(S^2)-memory path is unsafe
BLOCK_Q = 1024


def _sdpa_blockwise(q, k, v, *, causal, window, block_q=BLOCK_Q):
    """Memory-bounded attention: scan over query blocks (logits peak is
    [B,H,block_q,S] instead of [B,H,S,S]); online softmax is unnecessary when
    K stays whole per block, so plain softmax per Q-block is exact.  This is
    also the oracle for the Pallas flash kernel."""
    b, s, h, hd = q.shape
    bq = min(block_q, s)
    while s % bq:
        bq -= 1
    nq = s // bq
    kv = k.shape[2]
    rep = h // kv
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    qs = q.reshape(b, nq, bq, h, hd).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(s)

    def step(carry, inp):
        qb, i = inp
        logits = jnp.einsum("bqhd,bkhd->bhqk", qb, kk).astype(jnp.float32)
        logits = logits / (hd ** 0.5)
        qpos = i * bq + jnp.arange(bq)
        mask = jnp.ones((bq, s), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(qb.dtype)
        ob = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
        return carry, ob

    _, os_ = jax.lax.scan(step, 0, (qs, jnp.arange(nq)))
    return os_.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def attention(mesh, p: AttnParams, x, cfg, positions=None):
    """Full-sequence path (train / prefill).  x: [B, S, d]."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _project_qkv(p, x, cfg.n_heads, cfg.n_kv_heads, hd, positions,
                           cfg.rope_theta, cfg.norm_eps)
    dp = dp_axes(mesh)
    tp = tp_axes(mesh)
    q = constrain(q, mesh, P(dp, None, tp, None))
    k = constrain(k, mesh, P(dp, None, tp if cfg.n_kv_heads > 1 else None, None))
    if s > BLOCKWISE_THRESHOLD:
        o = _sdpa_blockwise(q, k, v, causal=cfg.causal,
                            window=cfg.sliding_window)
    else:
        o = _sdpa(q, k, v, causal=cfg.causal, window=cfg.sliding_window)
    o = o.reshape(b, s, cfg.n_heads * hd)
    y = o @ p.wo
    return constrain(y, mesh, P(dp, None, None)), KVCache(k, v)


def decode_attention(mesh, p: AttnParams, x, cache: KVCache, pos, cfg):
    """One-token decode.  x: [B, 1, d]; pos: [B] absolute position; the cache
    holds S_max slots (ring-buffered when sliding window is on)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(p, x, cfg.n_heads, cfg.n_kv_heads, hd,
                                   pos[:, None], cfg.rope_theta, cfg.norm_eps)
    s_max = cache.k.shape[1]
    slot = pos % s_max if cfg.sliding_window else jnp.minimum(pos, s_max - 1)
    k = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice(
        c, kn, (i, 0, 0)))(cache.k, k_new, slot)
    v = jax.vmap(lambda c, vn, i: jax.lax.dynamic_update_slice(
        c, vn, (i, 0, 0)))(cache.v, v_new, slot)

    kv = cfg.n_kv_heads
    rep = cfg.n_heads // kv
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) / hd ** 0.5
    kpos = jnp.arange(s_max)[None, :]
    if cfg.sliding_window:
        # ring buffer: valid slots are the last min(pos+1, window) writes
        age = (slot[:, None] - kpos) % s_max
        valid = (age < jnp.minimum(pos[:, None] + 1, s_max))
    else:
        valid = kpos <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, vv).reshape(b, 1, cfg.n_heads * hd)
    return o @ p.wo, KVCache(k, v)


def init_kv_cache(cfg, batch, seq_len, dtype=jnp.bfloat16) -> KVCache:
    s = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    hd = cfg.resolved_head_dim
    shape = (batch, s, cfg.n_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
