"""Mamba2 (SSD) block: chunked matmul-form sequence path (train/prefill) and
recurrent single-step decode path — the zamba2 backbone.

SSD recurrence per head (P = head_dim, N = d_state, scalar decay per head):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)        h: [P, N]
    y_t = h_t @ C_t + D * x_t
The chunked form turns the intra-chunk part into lower-triangular matmuls
(MXU-friendly; mirrored by the Pallas kernel in kernels/ssd.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

CONV_K = 4  # depthwise causal conv width


class MambaParams(NamedTuple):
    in_proj: jax.Array    # [d, 2*d_in + 2*N + H]  -> z, x, B, C, dt
    conv_w: jax.Array     # [K, d_in + 2*N] depthwise
    conv_b: jax.Array     # [d_in + 2*N]
    a_log: jax.Array      # [H] log(-A)
    d_skip: jax.Array     # [H]
    dt_bias: jax.Array    # [H]
    norm: jax.Array       # [d_in] gated RMSNorm scale
    out_proj: jax.Array   # [d_in, d]


class MambaState(NamedTuple):
    h: jax.Array          # [B, H, P, N] SSM state
    conv: jax.Array       # [B, K-1, d_in + 2*N] conv tail


def dims(cfg):
    d_in = cfg.ssm.expand * cfg.d_model
    n_heads = d_in // cfg.ssm.head_dim
    return d_in, n_heads, cfg.ssm.d_state, cfg.ssm.head_dim


def init_mamba_params(key, cfg, dtype=jnp.float32) -> MambaParams:
    d_in, h, n, p = dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    conv_ch = d_in + 2 * n
    return MambaParams(
        in_proj=dense_init(ks[0], (d, 2 * d_in + 2 * n + h), dtype=dtype),
        conv_w=(jax.random.normal(ks[1], (CONV_K, conv_ch)) * 0.1).astype(dtype),
        conv_b=jnp.zeros((conv_ch,), dtype),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        d_skip=jnp.ones((h,), dtype),
        dt_bias=jnp.zeros((h,), dtype),
        norm=jnp.ones((d_in,), dtype),
        out_proj=dense_init(ks[2], (d_in, d), dtype=dtype),
    )


def _split_proj(cfg, proj):
    d_in, h, n, p = dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b, tail=None):
    """Depthwise causal conv along time.  xbc: [B, T, C]; tail: [B, K-1, C]."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([tail, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k)) + b
    return jax.nn.silu(out), xp[:, -(k - 1):]


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int, h0=None):
    """Chunked SSD.  x: [B,T,H,P]; dt: [B,T,H]; b,c: [B,T,N].
    Returns (y [B,T,H,P], h_final [B,H,P,N])."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, t)
    while t % q:
        q -= 1
    nc = t // q
    a = -jnp.exp(a_log.astype(jnp.float32))                      # [H]
    dt = jax.nn.softplus(dt.astype(jnp.float32))                 # [B,T,H]
    la = dt * a[None, None, :]                                   # log-decay/step
    xr = (x.astype(jnp.float32) * dt[..., None]).reshape(bsz, nc, q, h, p)
    la = la.reshape(bsz, nc, q, h)
    br = b.astype(jnp.float32).reshape(bsz, nc, q, n)
    cr = c.astype(jnp.float32).reshape(bsz, nc, q, n)

    l_cum = jnp.cumsum(la, axis=2)                               # [B,NC,Q,H]
    # intra-chunk: M[t,s] = (c_t.b_s) * exp(L_t - L_s) for s<=t
    rel = l_cum[:, :, :, None, :] - l_cum[:, :, None, :, :]      # [B,NC,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bnqk,bnsk->bnqs", cr, br)                   # [B,NC,Q,Q]
    y_intra = jnp.einsum("bnqs,bnqsh,bnshp->bnqhp", cb, m, xr)

    # chunk state: S = sum_s exp(L_Q - L_s) x_s b_s^T   -> [B,NC,H,P,N]
    decay_to_end = jnp.exp(l_cum[:, :, -1:, :] - l_cum)          # [B,NC,Q,H]
    s_chunk = jnp.einsum("bnqh,bnqhp,bnqk->bnhpk", decay_to_end, xr, br)

    # cross-chunk scan over NC
    chunk_decay = jnp.exp(l_cum[:, :, -1, :])                    # [B,NC,H]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hprev, inp):
        s_c, dec = inp                                           # [B,H,P,N],[B,H]
        hnext = hprev * dec[..., None, None] + s_c
        return hnext, hprev

    hT, h_in = jax.lax.scan(step, h0,
                            (s_chunk.transpose(1, 0, 2, 3, 4),
                             chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                         # [B,NC,H,P,N]
    # y_cross[t] = exp(L_t) * (h_in @ c_t)
    y_cross = jnp.einsum("bnqh,bnhpk,bnqk->bnqhp", jnp.exp(l_cum), h_in, cr)

    y = (y_intra + y_cross).reshape(bsz, t, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y, hT


def mamba_block(p: MambaParams, cfg, x, state: Optional[MambaState] = None):
    """Sequence path.  x: [B, T, d] -> (y, final MambaState)."""
    bsz, t, d = x.shape
    d_in, h, n, pd = dims(cfg)
    z, xbc, dt = _split_proj(cfg, x @ p.in_proj)
    conv_tail = state.conv if state is not None else None
    xbc, tail = _causal_conv(xbc, p.conv_w, p.conv_b, conv_tail)
    xs, b, c = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(bsz, t, h, pd)
    dt = dt + p.dt_bias
    h0 = state.h if state is not None else None
    y, hT = ssd_chunked(xs, dt, p.a_log, b, c, p.d_skip, cfg.ssm.chunk, h0)
    y = y.reshape(bsz, t, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p.norm, cfg.norm_eps)
    return y @ p.out_proj, MambaState(hT, tail)


def mamba_decode(p: MambaParams, cfg, x, state: MambaState):
    """Single-token recurrent path.  x: [B, 1, d]."""
    bsz = x.shape[0]
    d_in, h, n, pd = dims(cfg)
    z, xbc, dt = _split_proj(cfg, x[:, 0] @ p.in_proj)
    # conv over stored tail + current input
    xp = jnp.concatenate([state.conv, xbc[:, None]], axis=1)     # [B,K,C]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", xp, p.conv_w) + p.conv_b)
    xs, b, c = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(bsz, h, pd)
    dt = jax.nn.softplus((dt + p.dt_bias).astype(jnp.float32))   # [B,H]
    a = -jnp.exp(p.a_log.astype(jnp.float32))
    dec = jnp.exp(dt * a[None])                                  # [B,H]
    upd = jnp.einsum("bhp,bk->bhpk", xs.astype(jnp.float32) * dt[..., None], b)
    hnew = state.h * dec[..., None, None] + upd
    y = jnp.einsum("bhpk,bk->bhp", hnew, c.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p.d_skip[None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, None]), p.norm, cfg.norm_eps)
    return y @ p.out_proj, MambaState(hnew, xp[:, 1:])


def init_mamba_state(cfg, batch, dtype=jnp.float32) -> MambaState:
    d_in, h, n, pd = dims(cfg)
    return MambaState(jnp.zeros((batch, h, pd, n), jnp.float32),
                      jnp.zeros((batch, CONV_K - 1, d_in + 2 * n), dtype))
