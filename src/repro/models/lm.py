"""Unified model stack for every assigned architecture.

One functional LM covering: dense transformers (granite/qwen*), MoE
transformers with interleaving + shared expert (llama4, mixtral, paper
models), hybrid Mamba2+shared-attention (zamba2), attention-free RWKV6, the
encoder-only audio backbone (hubert) and the VLM stub frontend (llava).

Everything is scan-over-layer-groups with stacked params (compile-time
tractability at 512 devices) and optional remat.  MoE groups call
``repro.core.moe_layer`` (training, Lina micro-op pipeline) or
``repro.core.serving.serve_moe_layer`` (inference, placement plans).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.moe import MoEParams, init_moe_params, moe_layer
from repro.core.serving import PlanArrays, serve_moe_layer
from repro.models.attention import (AttnParams, KVCache, attention,
                                    decode_attention, init_attn_params,
                                    init_kv_cache)
from repro.models.layers import constrain, dense_init, dp_axes, rms_norm, tp_axes
from repro.models import ssm as ssm_mod
from repro.models import rwkv as rwkv_mod

FRAME_DIM = 512      # audio stub frame-embedding dim
CE_CHUNK = 1024      # sequence chunk for the memory-bounded CE
MASK_EVERY = 13      # hubert deterministic mask pattern


class FFNParams(NamedTuple):
    w_in: jax.Array                  # [d, f]
    w_up: Optional[jax.Array]        # [d, f] (swiglu) or None
    w_out: jax.Array                 # [f, d]


class GroupParams(NamedTuple):
    """One scanned layer group (= `moe.every` transformer blocks)."""
    attn: AttnParams                 # stacked [every, ...]
    ln1: jax.Array                   # [every, d]
    ln2: jax.Array                   # [every, d]
    ffn: Optional[FFNParams]         # stacked [n_dense, ...] or None
    moe: Optional[MoEParams]         # one per group or None
    shared: Optional[FFNParams]      # shared expert (llama4) or None


class HybridParams(NamedTuple):
    mamba: Any                       # MambaParams stacked [L, ...]
    ln_m: jax.Array                  # [L, d]
    shared_attn: AttnParams          # single shared block
    shared_ffn: FFNParams
    ln_s1: jax.Array                 # [d]
    ln_s2: jax.Array                 # [d]


class RWKVStack(NamedTuple):
    blocks: Any                      # RWKVParams stacked [L, ...]
    ln1: jax.Array                   # [L, d]
    ln2: jax.Array                   # [L, d]


class LMParams(NamedTuple):
    embed: jax.Array                 # [V, d]
    patch_proj: Optional[jax.Array]  # [d, d] vision stub
    frame_proj: Optional[jax.Array]  # [FRAME_DIM, d] audio stub
    mask_emb: Optional[jax.Array]    # [FRAME_DIM] hubert mask embedding
    stack: Any                       # GroupParams | HybridParams | RWKVStack
    final_norm: jax.Array            # [d]
    lm_head: Optional[jax.Array]     # [d, V] or None (tied)


class LMCache(NamedTuple):
    kv: Optional[KVCache]            # stacked [G, every, ...] or [taps, ...]
    mamba: Optional[Any]             # MambaState stacked [L, ...]
    rwkv: Optional[Any]              # RWKVState stacked [L, ...]
    pos: jax.Array                   # [B] next position


class ModelOutput(NamedTuple):
    loss: Optional[jax.Array]
    logits: Optional[jax.Array]
    aux_loss: jax.Array
    expert_choices: Optional[jax.Array]   # [n_moe_layers, T] top-1
    cache: Optional[LMCache]
    a2a_marker: Optional[jax.Array] = None  # zero scalar data-dependent on
    #                                         every MoE layer's a2a micro-ops
    #                                         (Lina's reduce-ordering signal)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_ffn(key, d, f, ffn_type, dtype) -> FFNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return FFNParams(
        dense_init(k1, (d, f), dtype=dtype),
        dense_init(k2, (d, f), dtype=dtype) if ffn_type == "swiglu" else None,
        dense_init(k3, (f, d), dtype=dtype),
    )


def _stack(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> LMParams:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    embed = (jax.random.normal(keys[0], (cfg.vocab_size, d)) * d ** -0.5
             ).astype(dtype)

    patch_proj = dense_init(keys[1], (d, d), dtype=dtype) \
        if cfg.frontend == "vision_stub" else None
    frame_proj = dense_init(keys[1], (FRAME_DIM, d), dtype=dtype) \
        if cfg.frontend == "audio_stub" else None
    mask_emb = jnp.zeros((FRAME_DIM,), dtype) \
        if cfg.frontend == "audio_stub" else None

    hd = cfg.resolved_head_dim
    if cfg.layer_pattern:                                  # hybrid (zamba2)
        n_l = cfg.n_layers
        mamba = _stack(lambda k: ssm_mod.init_mamba_params(k, cfg, dtype),
                       keys[2], n_l)
        stack = HybridParams(
            mamba=mamba,
            ln_m=jnp.ones((n_l, d), dtype),
            shared_attn=init_attn_params(keys[3], d, cfg.n_heads,
                                         cfg.n_kv_heads, hd, dtype=dtype),
            shared_ffn=_init_ffn(keys[4], d, cfg.d_ff, cfg.ffn_type, dtype),
            ln_s1=jnp.ones((d,), dtype),
            ln_s2=jnp.ones((d,), dtype),
        )
    elif cfg.attention_free:                               # rwkv6
        n_l = cfg.n_layers
        stack = RWKVStack(
            blocks=_stack(lambda k: rwkv_mod.init_rwkv_params(k, cfg, dtype),
                          keys[2], n_l),
            ln1=jnp.ones((n_l, d), dtype),
            ln2=jnp.ones((n_l, d), dtype),
        )
    else:                                                   # transformer
        every = cfg.moe.every if cfg.moe.enabled else 1
        n_groups = cfg.n_layers // every
        n_dense = (every - 1) if cfg.moe.enabled else every

        def one_group(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            attn = _stack(lambda kk: init_attn_params(
                kk, d, cfg.n_heads, cfg.n_kv_heads, hd,
                qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype),
                k1, every)
            ffn = _stack(lambda kk: _init_ffn(kk, d, cfg.d_ff, cfg.ffn_type,
                                              dtype), k2, n_dense) \
                if n_dense else None
            moe = init_moe_params(k3, d, cfg.moe.d_ff or cfg.d_ff,
                                  cfg.moe.n_experts, cfg.ffn_type, dtype) \
                if cfg.moe.enabled else None
            shared = _init_ffn(k4, d, cfg.moe.d_ff or cfg.d_ff, cfg.ffn_type,
                               dtype) \
                if (cfg.moe.shared_expert or cfg.moe.shortcut) else None
            return GroupParams(attn, jnp.ones((every, d), dtype),
                               jnp.ones((every, d), dtype), ffn, moe, shared)

        stack = _stack(one_group, keys[2], n_groups)

    lm_head = None if cfg.tie_embeddings else dense_init(
        keys[5], (d, cfg.vocab_size), dtype=dtype)
    return LMParams(embed, patch_proj, frame_proj, mask_emb, stack,
                    jnp.ones((d,), dtype), lm_head)


# ---------------------------------------------------------------------------
# block applications
# ---------------------------------------------------------------------------

def _ffn_apply(p: FFNParams, x, ffn_type, mesh, tensor_parallel=True):
    dp = dp_axes(mesh)
    h = x @ p.w_in
    h = constrain(h, mesh, P(dp, None,
                             tp_axes(mesh) if tensor_parallel else None))
    if ffn_type == "swiglu":
        h = jax.nn.silu(h) * (x @ p.w_up)
    else:
        h = jax.nn.gelu(h)
    y = h @ p.w_out
    return constrain(y, mesh, P(dp, None, None))


def _tree_idx(tree, i):
    return jax.tree.map(lambda a: a[i] if a is not None else None, tree,
                        is_leaf=lambda a: a is None)


def _group_apply(mesh, cfg, gp: GroupParams, x, *, lina, serve_plan=None,
                 serve_top_k=None, dispatch_backend="scatter", fsdp=False):
    """Apply one layer group on [B, S, d].
    Returns (x, aux, top1_experts, a2a_token)."""
    every = cfg.moe.every if cfg.moe.enabled else 1
    aux = jnp.zeros((), jnp.float32)
    tok = jnp.zeros((), jnp.float32)
    top1 = None
    b, s, d = x.shape
    for j in range(every):
        a_p = _tree_idx(gp.attn, j)
        h = rms_norm(x, gp.ln1[j], cfg.norm_eps)
        y, _ = attention(mesh, a_p, h, cfg)
        x = x + y
        h = rms_norm(x, gp.ln2[j], cfg.norm_eps)
        is_moe = cfg.moe.enabled and j == every - 1
        if not is_moe:
            ffn_p = _tree_idx(gp.ffn, j) if (gp.ffn is not None and
                                             getattr(gp.ffn.w_in, "ndim", 0) > 2) \
                else gp.ffn
            x = x + _ffn_apply(ffn_p, h, cfg.ffn_type, mesh,
                                   cfg.tensor_parallel)
        else:
            if serve_plan is not None:
                h2 = h.reshape(b * s, d)
                y2, eidx, _ = serve_moe_layer(mesh, h2, gp.moe, cfg.moe,
                                              serve_plan,
                                              ffn_type=cfg.ffn_type,
                                              top_k=serve_top_k)
                moe_y = y2.reshape(b, s, d)
                a = jnp.zeros((), jnp.float32)
                sc_fused = False
            else:
                # ScMoE variant: the dense shortcut branch is fused into the
                # MoE shard body so it computes under the a2a shadow and is
                # summed into the combine (same math as the outer add).
                sc = gp.shared if (cfg.moe.shortcut and
                                   gp.shared is not None) else None
                out = moe_layer(mesh, h, gp.moe, cfg.moe,
                                ffn_type=cfg.ffn_type,
                                dispatch_backend=dispatch_backend,
                                lina=lina, fsdp=fsdp, shortcut_params=sc)
                moe_y, a, eidx = out.y, out.aux_loss, out.expert_idx
                tok = tok + out.a2a_token
                sc_fused = sc is not None
            if gp.shared is not None and not sc_fused:
                moe_y = moe_y + _ffn_apply(gp.shared, h, cfg.ffn_type,
                                           mesh, cfg.tensor_parallel)
            x = x + moe_y
            aux = aux + a
            top1 = eidx[:, 0]
    return x, aux, top1, tok


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def cast_for_compute(cfg, params: LMParams) -> LMParams:
    """Master (fp32) params -> compute dtype; int/float8 leaves untouched."""
    dt = jnp.dtype(cfg.dtype)
    def one(p):
        if p.dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
            return p.astype(dt)
        return p
    return jax.tree.map(one, params)


def embed_inputs(cfg, params: LMParams, *, tokens=None, patches=None,
                 frames=None, mask=None):
    """Returns (x [B,S,d], loss_mask [B,S] or None extra semantics)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_stub":
        f = frames
        if mask is not None:
            f = jnp.where(mask[..., None], params.mask_emb.astype(f.dtype), f)
        return (f @ params.frame_proj).astype(dtype)
    x = params.embed[tokens].astype(dtype)
    if cfg.frontend == "vision_stub":
        pe = (patches.astype(params.patch_proj.dtype) @ params.patch_proj
              ).astype(dtype)
        x = jnp.concatenate([pe, x], axis=1)
    return x


def unembed_weight(params: LMParams):
    return params.embed.T if params.lm_head is None else params.lm_head


def chunked_ce_loss(mesh, x, w_unembed, labels, loss_mask, chunk=CE_CHUNK):
    """Cross-entropy without materializing [B,S,V] logits: scan over
    sequence chunks (vocab stays `model`-sharded inside each chunk)."""
    b, s, d = x.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    xs = x.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, c).transpose(1, 0, 2)
    ms = loss_mask.reshape(b, nc, c).transpose(1, 0, 2)

    def step(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        logits = (xc @ w_unembed).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    # remat: recompute the chunk logits in backward (one matmul) instead of
    # saving [B, chunk, V] fp32 per chunk (2.5GB/device at 150k vocab)
    step = jax.checkpoint(step,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _run_stack(mesh, cfg, params: LMParams, x, *, lina=True, serve_plan=None,
               serve_top_k=None, dispatch_backend="scatter", fsdp=False):
    """Full-sequence stack application.
    Returns (x, aux, expert_choices, a2a_marker)."""
    dp = dp_axes(mesh)
    x = constrain(x, mesh, P(dp, None, None))
    if isinstance(params.stack, HybridParams):
        return _run_hybrid(mesh, cfg, params.stack, x)
    if isinstance(params.stack, RWKVStack):
        return _run_rwkv(mesh, cfg, params.stack, x)

    gp_stack = params.stack

    def body(x, gp):
        if cfg.seq_parallel:
            # Megatron-SP: the carry (and everything outside attention) lives
            # sequence-sharded over `model`; XLA gathers around attention.
            x = constrain(x, mesh, P(dp, tp_axes(mesh), None))
        x, aux, top1, tok = _group_apply(mesh, cfg, gp, x, lina=lina,
                                         serve_plan=serve_plan,
                                         serve_top_k=serve_top_k,
                                         dispatch_backend=dispatch_backend,
                                         fsdp=fsdp)
        if top1 is None:
            top1 = jnp.zeros((x.shape[0] * x.shape[1],), jnp.int32)
        return x, (aux, top1, tok)

    if cfg.remat:
        # save only the layer boundaries; recompute everything inside the
        # block in backward (activation memory = O(layers * hidden), the
        # standard full-remat policy for big-model training)
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (auxs, top1s, toks) = jax.lax.scan(body, x, gp_stack)
    aux = auxs.sum()
    experts = top1s if cfg.moe.enabled else None
    return x, aux, experts, toks.sum()


def _run_hybrid(mesh, cfg, hp: HybridParams, x):
    taps = jnp.array([ch in "A*" for ch in cfg.layer_pattern], jnp.bool_)
    b, s, d = x.shape
    kv_shape = None  # sequence path: no cache maintenance

    def shared_block(x):
        h = rms_norm(x, hp.ln_s1, cfg.norm_eps)
        y, _ = attention(mesh, hp.shared_attn, h, cfg)
        x = x + y
        h = rms_norm(x, hp.ln_s2, cfg.norm_eps)
        return x + _ffn_apply(hp.shared_ffn, h, cfg.ffn_type, mesh)

    def body(x, inp):
        mp, ln, tap = inp
        h = rms_norm(x, ln, cfg.norm_eps)
        y, _ = ssm_mod.mamba_block(mp, cfg, h)
        x = x + y
        x = jax.lax.cond(tap, shared_block, lambda z: z, x)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, (hp.mamba, hp.ln_m, taps))
    return x, jnp.zeros(()), None, jnp.zeros((), jnp.float32)


def _run_rwkv(mesh, cfg, st: RWKVStack, x):
    def body(x, inp):
        bp, l1, l2 = inp
        h = rms_norm(x, l1, cfg.norm_eps)
        y, _, _ = rwkv_mod.time_mix(bp, cfg, h)
        x = x + y
        h = rms_norm(x, l2, cfg.norm_eps)
        y, _ = rwkv_mod.channel_mix(bp, h)
        return x + y, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, (st.blocks, st.ln1, st.ln2))
    return x, jnp.zeros(()), None, jnp.zeros((), jnp.float32)


def forward_train(mesh, cfg, params: LMParams, batch: dict, *, lina=True,
                  dispatch_backend="scatter", fsdp=False) -> ModelOutput:
    """Training forward: returns (loss, aux, expert_choices)."""
    params = cast_for_compute(cfg, params)
    tokens = batch.get("tokens")
    if cfg.frontend == "audio_stub":
        s = batch["frames"].shape[1]
        pos = jnp.arange(s)
        mask = (pos % MASK_EVERY) == (MASK_EVERY - 1)
        mask = jnp.broadcast_to(mask[None], batch["frames"].shape[:2])
        x = embed_inputs(cfg, params, frames=batch["frames"], mask=mask)
        labels, loss_mask = batch["labels"], mask.astype(jnp.float32)
    elif cfg.frontend == "vision_stub":
        x = embed_inputs(cfg, params, tokens=tokens, patches=batch["patches"])
        npatch = batch["patches"].shape[1]
        # next-token prediction on the text region only
        lab_txt = batch["labels"]
        pad = jnp.zeros((tokens.shape[0], npatch), lab_txt.dtype)
        labels = jnp.concatenate([pad, lab_txt], axis=1)
        lm = jnp.concatenate([jnp.zeros_like(pad, jnp.float32),
                              jnp.ones_like(lab_txt, jnp.float32)], axis=1)
        loss_mask = lm
    else:
        x = embed_inputs(cfg, params, tokens=tokens)
        labels = batch["labels"]
        loss_mask = jnp.ones_like(labels, jnp.float32)

    x, aux, experts, marker = _run_stack(mesh, cfg, params, x, lina=lina,
                                         dispatch_backend=dispatch_backend,
                                         fsdp=fsdp)
    x = rms_norm(x, params.final_norm, cfg.norm_eps)
    loss = chunked_ce_loss(mesh, x, unembed_weight(params), labels, loss_mask)
    total = loss + cfg.moe.aux_loss_weight * 0 + aux  # aux already weighted
    return ModelOutput(total, None, aux, experts, None, marker)


def forward_prefill(mesh, cfg, params: LMParams, batch: dict, *, lina=False,
                    serve_plan=None, serve_top_k=None, fsdp=False,
                    with_cache: bool = False) -> ModelOutput:
    """Serving prefill: last-position logits (+ optional KV/state cache).

    The dry-run lowers with_cache=False (cache construction is exercised by
    the decode cells, whose input_specs carry the cache)."""
    params = cast_for_compute(cfg, params)
    if cfg.frontend == "audio_stub":
        x = embed_inputs(cfg, params, frames=batch["frames"])
    elif cfg.frontend == "vision_stub":
        x = embed_inputs(cfg, params, tokens=batch["tokens"],
                         patches=batch["patches"])
    else:
        x = embed_inputs(cfg, params, tokens=batch["tokens"])
    x, aux, experts, marker = _run_stack(mesh, cfg, params, x, lina=lina,
                                         serve_plan=serve_plan,
                                         serve_top_k=serve_top_k, fsdp=fsdp)
    x = rms_norm(x, params.final_norm, cfg.norm_eps)
    last = x[:, -1]
    logits = last @ unembed_weight(params)
    return ModelOutput(None, logits, aux, experts, None, marker)


# -- decode ------------------------------------------------------------------

def init_cache(cfg, batch, seq_len, dtype=jnp.bfloat16) -> LMCache:
    pos = jnp.zeros((batch,), jnp.int32)
    if cfg.layer_pattern:
        n_taps = sum(ch in "A*" for ch in cfg.layer_pattern)
        kv = init_kv_cache(cfg, batch, seq_len, dtype)
        kv = KVCache(jnp.broadcast_to(kv.k[None], (n_taps, *kv.k.shape)),
                     jnp.broadcast_to(kv.v[None], (n_taps, *kv.v.shape)))
        ms = ssm_mod.init_mamba_state(cfg, batch)
        ms = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)), ms)
        return LMCache(kv, ms, None, pos)
    if cfg.attention_free:
        rs = rwkv_mod.init_rwkv_state(cfg, batch)
        rs = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)), rs)
        return LMCache(None, None, rs, pos)
    every = cfg.moe.every if cfg.moe.enabled else 1
    n_groups = cfg.n_layers // every
    kv = init_kv_cache(cfg, batch, seq_len, dtype)
    kv = KVCache(
        jnp.broadcast_to(kv.k[None, None], (n_groups, every, *kv.k.shape)),
        jnp.broadcast_to(kv.v[None, None], (n_groups, every, *kv.v.shape)))
    return LMCache(kv, None, None, pos)


def decode_step(mesh, cfg, params: LMParams, cache: LMCache, token,
                *, lina=False, serve_plan=None, serve_top_k=None,
                fsdp=False) -> tuple:
    """One decode step.  token: [B] int32.

    Returns (logits [B,V], cache, expert_choices) where expert_choices is
    the per-MoE-layer top-1 expert index of each row ([n_moe_layers, B]
    int32; None for non-MoE stacks) — callers roll path-ID state with it so
    popularity estimation keeps working during generation.

    ``serve_plan`` may be a single ``PlanArrays`` shared by every MoE layer
    or a *stacked* PlanArrays (leading layer dim, see
    ``core.serving.stack_plan_arrays``) giving each layer its own placement.
    """
    params = cast_for_compute(cfg, params)
    dtype = jnp.dtype(cfg.dtype)
    x = params.embed[token][:, None].astype(dtype)       # [B,1,d]
    pos = cache.pos
    b = token.shape[0]
    d = cfg.d_model

    if isinstance(params.stack, HybridParams):
        hp = params.stack
        taps = jnp.array([ch in "A*" for ch in cfg.layer_pattern], jnp.bool_)

        def body(carry, inp):
            x, kvt, tap_i = carry
            mp, ln, ms_k, tap = inp
            h = rms_norm(x, ln, cfg.norm_eps)
            y, ms_new = ssm_mod.mamba_decode(mp, cfg, h, ms_k)

            def run_tap(args):
                x, kvt, tap_i = args
                h = rms_norm(x, hp.ln_s1, cfg.norm_eps)
                kv_i = jax.tree.map(lambda a: a[tap_i], kvt)
                y, kv_new = decode_attention(mesh, hp.shared_attn, h, kv_i,
                                             pos, cfg)
                kvt = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new, tap_i, 0), kvt, kv_new)
                x = x + y
                h2 = rms_norm(x, hp.ln_s2, cfg.norm_eps)
                x = x + _ffn_apply(hp.shared_ffn, h2, cfg.ffn_type, mesh)
                return x, kvt, tap_i + 1

            x = x + y
            x, kvt, tap_i = jax.lax.cond(tap, run_tap,
                                         lambda a: a, (x, kvt, tap_i))
            return (x, kvt, tap_i), ms_new

        (x, kvt, _), ms_new = jax.lax.scan(
            body, (x, cache.kv, jnp.zeros((), jnp.int32)),
            (hp.mamba, hp.ln_m, cache.mamba, taps))
        new_cache = LMCache(kvt, ms_new, None, pos + 1)
        experts = None
    elif isinstance(params.stack, RWKVStack):
        st = params.stack

        def body(x, inp):
            bp, l1, l2, rs = inp
            h = rms_norm(x, l1, cfg.norm_eps)
            # single-token time-mix via the chunked path (T=1); states are
            # stored f32, cast at use so the scan carry stays compute-dtype
            x_prev = rs.x_tm[:, None].astype(h.dtype)
            lw, k, v, r, g = rwkv_mod._tm_projections(bp, cfg, h, x_prev)
            hh, hd = rwkv_mod._heads(cfg)
            y, sT = rwkv_mod.wkv_chunked(r, k, v, lw, bp.u, hh, hd, 1, rs.s)
            y = rms_norm(y.astype(x.dtype) * g.astype(x.dtype), bp.ln_x,
                         cfg.norm_eps)
            x = x + y @ bp.wo
            h2 = rms_norm(x, l2, cfg.norm_eps)
            y2, last_cm = rwkv_mod.channel_mix(bp, h2,
                                               rs.x_cm.astype(h2.dtype))
            x = x + y2
            return x, rwkv_mod.RWKVState(
                sT, h[:, -1].astype(jnp.float32),
                last_cm.astype(jnp.float32))

        x, rs_new = jax.lax.scan(body, x, (st.blocks, st.ln1, st.ln2,
                                           cache.rwkv))
        new_cache = LMCache(None, None, rs_new, pos + 1)
        experts = None
    else:
        gp_stack = params.stack
        every = cfg.moe.every if cfg.moe.enabled else 1
        stacked_plan = serve_plan is not None and serve_plan.stacked

        def body(x, inp):
            if stacked_plan:
                gp, kv_g, plan = inp
            else:
                gp, kv_g = inp
                plan = serve_plan
            new_kvs = []
            top1 = jnp.zeros((b,), jnp.int32)
            for j in range(every):
                a_p = _tree_idx(gp.attn, j)
                kv_j = jax.tree.map(lambda a: a[j], kv_g)
                h = rms_norm(x, gp.ln1[j], cfg.norm_eps)
                y, kv_new = decode_attention(mesh, a_p, h, kv_j, pos, cfg)
                new_kvs.append(kv_new)
                x = x + y
                h = rms_norm(x, gp.ln2[j], cfg.norm_eps)
                is_moe = cfg.moe.enabled and j == every - 1
                if not is_moe:
                    ffn_p = _tree_idx(gp.ffn, j) if (gp.ffn is not None and
                                                     gp.ffn.w_in.ndim > 2) \
                        else gp.ffn
                    x = x + _ffn_apply(ffn_p, h, cfg.ffn_type, mesh,
                                   cfg.tensor_parallel)
                else:
                    if plan is not None:
                        h2 = h.reshape(b, d)
                        y2, eidx, _ = serve_moe_layer(
                            mesh, h2, gp.moe, cfg.moe, plan,
                            ffn_type=cfg.ffn_type, top_k=serve_top_k)
                        moe_y = y2.reshape(b, 1, d)
                    else:
                        out = moe_layer(mesh, h, gp.moe, cfg.moe,
                                        ffn_type=cfg.ffn_type, lina=lina,
                                        fsdp=fsdp,
                                        top_k=serve_top_k)
                        moe_y, eidx = out.y, out.expert_idx
                    top1 = eidx[:, 0].astype(jnp.int32)
                    if gp.shared is not None:
                        moe_y = moe_y + _ffn_apply(gp.shared, h, cfg.ffn_type,
                                                   mesh)
                    x = x + moe_y
            kv_stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_kvs)
            return x, (kv_stacked, top1)

        xs = (gp_stack, cache.kv, serve_plan) if stacked_plan \
            else (gp_stack, cache.kv)
        x, (kv_new, top1s) = jax.lax.scan(body, x, xs)
        new_cache = LMCache(kv_new, None, None, pos + 1)
        experts = top1s if cfg.moe.enabled else None

    x = rms_norm(x, params.final_norm, cfg.norm_eps)
    logits = x[:, 0] @ unembed_weight(params)
    return logits, new_cache, experts
