"""Model zoo: one functional stack covering every assigned architecture."""
from repro.models.lm import (
    LMParams, LMCache, ModelOutput, init_params, init_cache,
    forward_train, forward_prefill, decode_step,
)
from repro.models.attention import AttnParams, KVCache, attention, init_kv_cache
