"""Shared primitives: norms, RoPE, initializers, sharding helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import axes


def dense_init(key, shape, scale_axis: int = 0, dtype=jnp.float32):
    scale = shape[scale_axis] ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def rope(q, k, positions, theta: float = 10_000.0):
    """Rotary embeddings.  q/k: [..., S, H, hd]; positions: [..., S]."""
    hd = q.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]

    def rot(x):
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        return jnp.concatenate([x1 * cos - x2 * sin,
                                x1 * sin + x2 * cos], axis=-1).astype(x.dtype)

    return rot(q), rot(k)


def ffn_branch(x, w_in, w_up, w_out, ffn_type: str):
    """The bare dense-FFN math (no sharding hints): swiglu or gelu.

    Single source of truth for the dense branch so the shortcut-connected
    MoE variant (ScMoE — the branch fused into ``core.moe._moe_shard_body``
    under the a2a shadow) and the outer shared-expert add compute the exact
    same function; the numerical-equivalence tests rely on that.
    """
    h = x @ w_in
    if ffn_type == "swiglu":
        h = jax.nn.silu(h) * (x @ w_up)
    else:
        h = jax.nn.gelu(h)
    return h @ w_out


def axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return n


def safe_spec(mesh, spec: P, shape) -> P:
    """Drop mesh axes from dims they do not divide (e.g. 56 heads on a
    16-way `model` axis) so constraints never force padded shardings."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is not None and dim % axis_size(mesh, axes) != 0:
            axes = None
        out.append(axes)
    return P(*out)


def constrain(x, mesh, spec: P):
    """Sharding hint; no-op off-mesh (CPU smoke tests on 1 device)."""
    if mesh is None or mesh.size == 1:
        return x
    spec = safe_spec(mesh, spec, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def dp_axes(mesh) -> tuple:
    return axes.dp_axes(mesh)


def tp_axes(mesh):
    if mesh is not None and axes.TP in mesh.axis_names:
        return axes.MP_AXES
    return axes.MODEL
