"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Recurrence per head (hd-dim keys/values, diagonal data-dependent decay w_t):
    y_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
Sequence path: chunk-vectorized — an inner scan over the chunk position
(vectorized across all chunks) + an outer scan carrying cross-chunk state,
so sequential depth is Q + T/Q instead of T.  Decode: single recurrent step.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

LORA_R = 32  # low-rank size of the data-dependent decay


class RWKVParams(NamedTuple):
    # time-mix
    mu: jax.Array        # [5, d]  token-shift lerp weights for w,k,v,r,g
    w0: jax.Array        # [d]     decay base
    w_a: jax.Array       # [d, R]  decay lora
    w_b: jax.Array       # [R, d]
    wk: jax.Array        # [d, d]
    wv: jax.Array        # [d, d]
    wr: jax.Array        # [d, d]
    wg: jax.Array        # [d, d]
    u: jax.Array         # [d]     bonus
    wo: jax.Array        # [d, d]
    ln_x: jax.Array      # [d]     group-norm-ish scale on the head outputs
    # channel-mix
    mu_c: jax.Array      # [2, d]
    ck: jax.Array        # [d, f]
    cv: jax.Array        # [f, d]
    cr: jax.Array        # [d, d]


class RWKVState(NamedTuple):
    s: jax.Array         # [B, H, hd, hd] wkv state
    x_tm: jax.Array      # [B, d] last token (time-mix shift)
    x_cm: jax.Array      # [B, d] last token (channel-mix shift)


def init_rwkv_params(key, cfg, dtype=jnp.float32) -> RWKVParams:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 10)
    return RWKVParams(
        mu=jnp.full((5, d), 0.5, dtype),
        w0=jnp.full((d,), -2.0, dtype),
        w_a=(jax.random.normal(ks[0], (d, LORA_R)) * 0.01).astype(dtype),
        w_b=(jax.random.normal(ks[1], (LORA_R, d)) * 0.01).astype(dtype),
        wk=dense_init(ks[2], (d, d), dtype=dtype),
        wv=dense_init(ks[3], (d, d), dtype=dtype),
        wr=dense_init(ks[4], (d, d), dtype=dtype),
        wg=dense_init(ks[5], (d, d), dtype=dtype),
        u=jnp.zeros((d,), dtype),
        wo=dense_init(ks[6], (d, d), dtype=dtype),
        ln_x=jnp.ones((d,), dtype),
        mu_c=jnp.full((2, d), 0.5, dtype),
        ck=dense_init(ks[7], (d, f), dtype=dtype),
        cv=dense_init(ks[8], (f, d), dtype=dtype),
        cr=dense_init(ks[9], (d, d), dtype=dtype),
    )


def _heads(cfg):
    hd = cfg.ssm.head_dim
    return cfg.d_model // hd, hd


def _tm_projections(p: RWKVParams, cfg, x, x_prev):
    """x: [B,T,d]; x_prev: same, shifted by one (data-dependent lerp)."""
    mix = lambda i: x + (x_prev - x) * p.mu[i]
    w_in, xk, xv, xr, xg = (mix(i) for i in range(5))
    # data-dependent decay (lora): w in (0,1), log-decay lw < 0
    lw = -jnp.exp(p.w0 + jnp.tanh(w_in.astype(jnp.float32) @ p.w_a) @ p.w_b)
    k, v = xk @ p.wk, xv @ p.wv
    r, g = xr @ p.wr, jax.nn.silu(xg @ p.wg)
    return lw, k, v, r, g


def wkv_chunked(r, k, v, lw, u, n_heads, hd, chunk, s0=None):
    """Chunk-vectorized WKV.  r/k/v: [B,T,d]; lw: [B,T,d] log decays.
    Returns (y [B,T,d], s_final [B,H,hd,hd])."""
    bsz, t, d = r.shape
    q = min(chunk, t)
    while t % q:
        q -= 1
    nc = t // q
    shp = (bsz, nc, q, n_heads, hd)
    rr, kk, vv, ww = (a.astype(jnp.float32).reshape(shp) for a in (r, k, v, lw))
    uu = u.astype(jnp.float32).reshape(n_heads, hd)

    # inner scan over within-chunk position, vectorized over (B, NC, H)
    def inner(carry, inp):
        s_loc = carry                                   # [B,NC,H,hd,hd]
        r_t, k_t, v_t, w_t = inp                        # [B,NC,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]      # [B,NC,H,hd,hd]
        att = s_loc + uu[None, None, :, :, None] * kv
        y_t = jnp.einsum("bnhk,bnhkv->bnhv", r_t, att)
        s_new = jnp.exp(w_t)[..., None] * s_loc + kv
        return s_new, y_t

    seq = tuple(a.transpose(2, 0, 1, 3, 4) for a in (rr, kk, vv, ww))
    s_loc0 = jnp.zeros((bsz, nc, n_heads, hd, hd), jnp.float32)
    s_chunk, y_local = jax.lax.scan(inner, s_loc0, seq)
    y_local = y_local.transpose(1, 2, 0, 3, 4)          # [B,NC,Q,H,hd]

    # cross-chunk: carry state, apply decayed contribution per position.
    lcum = jnp.cumsum(ww, axis=2)                       # [B,NC,Q,H,hd]
    lprev = jnp.pad(lcum, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :-1]
    if s0 is None:
        s0 = jnp.zeros((bsz, n_heads, hd, hd), jnp.float32)

    def outer(s, inp):
        s_c, dec_q, r_dec = inp
        # y_cross[t] = (r_t * exp(lprev_t)) @ s
        y_c = jnp.einsum("bqhk,bhkv->bqhv", r_dec, s)
        s_next = jnp.exp(dec_q)[..., None] * s + s_c
        return s_next, y_c

    r_dec = rr * jnp.exp(lprev)                          # [B,NC,Q,H,hd]
    sT, y_cross = jax.lax.scan(
        outer, s0, (s_chunk.transpose(1, 0, 2, 3, 4),
                    lcum[:, :, -1].transpose(1, 0, 2, 3),
                    r_dec.transpose(1, 0, 2, 3, 4)))
    y = y_local + y_cross.transpose(1, 0, 2, 3, 4)
    return y.reshape(bsz, t, d), sT


def time_mix(p: RWKVParams, cfg, x, state: Optional[RWKVState] = None):
    bsz, t, d = x.shape
    h, hd = _heads(cfg)
    x_last = state.x_tm[:, None] if state is not None else jnp.zeros_like(x[:, :1])
    x_prev = jnp.concatenate([x_last, x[:, :-1]], axis=1)
    lw, k, v, r, g = _tm_projections(p, cfg, x, x_prev)
    s0 = state.s if state is not None else None
    y, sT = wkv_chunked(r, k, v, lw, p.u, h, hd, cfg.ssm.chunk, s0)
    y = rms_norm(y.astype(x.dtype) * g, p.ln_x, cfg.norm_eps)
    return y @ p.wo, sT, x[:, -1]


def channel_mix(p: RWKVParams, x, x_last=None):
    first = jnp.zeros_like(x[:, :1]) if x_last is None else x_last[:, None]
    x_prev = jnp.concatenate([first, x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p.mu_c[0]
    xr = x + (x_prev - x) * p.mu_c[1]
    kk = jnp.square(jax.nn.relu(xk @ p.ck))
    return jax.nn.sigmoid(xr @ p.cr) * (kk @ p.cv), x[:, -1]


def init_rwkv_state(cfg, batch) -> RWKVState:
    h, hd = _heads(cfg)
    return RWKVState(jnp.zeros((batch, h, hd, hd), jnp.float32),
                     jnp.zeros((batch, cfg.d_model), jnp.float32),
                     jnp.zeros((batch, cfg.d_model), jnp.float32))
