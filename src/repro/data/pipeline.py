"""Deterministic synthetic LM data pipeline.

Design goals (1000+-node readiness):
  * deterministic per (seed, step, host-shard) — restart at step k
    regenerates the identical batch (checkpoint/restart bitwise tests rely
    on this, and it is how real fault-tolerant loaders index into a fixed
    dataset order);
  * host-sharded: each data-parallel host reads only its slice;
  * prefetching with a bounded queue (straggler decoupling — a slow step
    never stalls the generator thread, paper [36]'s tiny-task intuition).

The token stream is a mixture of Zipf-distributed unigrams with a Markov
flavor so that (a) CE loss decreases meaningfully when training and (b) MoE
gating sees *structured*, non-uniform tokens — which is what makes expert
popularity skewed at inference (paper §2.2, Fig. 6).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Deterministic structured token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        # fixed Zipf unigram distribution + a sparse "bigram successor" map
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.unigram = p / p.sum()
        self.successor = rng.randint(0, v, size=(v,), dtype=np.int64)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 613 + cfg.host_id) % (2 ** 31 - 1))
        b, s = per_host, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self.unigram)
        # Markov structure: with p=0.5 the next token is the fixed successor
        follow = rng.rand(b, s) < 0.5
        toks[:, 1:][follow] = self.successor[toks[:, :-1][follow]]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_batch_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    ds = SyntheticLM(cfg)
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1


class Prefetcher:
    """Bounded-queue background prefetch (straggler decoupling)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
