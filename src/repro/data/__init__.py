"""Data substrate: deterministic synthetic LM pipeline, sharded + prefetched."""
from repro.data.pipeline import (DataConfig, SyntheticLM, make_batch_iterator,
                                 Prefetcher)
