"""Unified tracing + metrics for serving and training (the §3 substrate).

Lina's design is justified by a *measurement* — §3 attributes step time to
all-to-all vs compute before §4/§5 spend that attribution.  ``repro.obs``
is the first-class home for producing the same breakdown here:

  ``tracer``   — nested spans with JSON + Chrome ``trace_event`` export
                 (open in Perfetto) and a no-op disabled fast path;
  ``metrics``  — counters / gauges / fixed-bucket histograms with
                 Prometheus-text and JSON snapshot export;
  ``profiler`` — guarded ``jax.profiler`` trace sessions plus the
                 overlap-phase attribution that turns "fraction of a2a
                 hidden" into a trace-queryable quantity.

``ObsContext`` bundles one tracer + one registry; the serving stack shares
a single context (``MoEServer`` owns one, ``ServingEngine`` inherits or
overrides it), the trainer owns its own.  ``python -m repro.obs validate``
checks an exported trace against the span-tree invariants (CI gates on it).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

from repro.obs import tracer as tracer_mod
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               parse_prometheus)
from repro.obs.profiler import (StepProfiler, attribute_overlap,
                                hidden_fraction, trace_session)
from repro.obs.tracer import (NOOP, Span, Tracer, check_span_tree,
                              to_chrome, to_json, tree_from_chrome)

__all__ = [
    "ObsContext", "Tracer", "Span", "NOOP", "MetricsRegistry", "Counter",
    "Gauge", "Histogram", "parse_prometheus", "to_json", "to_chrome",
    "tree_from_chrome", "check_span_tree", "trace_session", "StepProfiler",
    "attribute_overlap", "hidden_fraction",
]


@dataclass
class ObsContext:
    """One tracer + one metrics registry, shared across a subsystem stack.
    Metrics are always live (counter bumps are dict lookups — the ledgers
    must be queryable even in production); span recording is opt-in."""
    tracer: Tracer
    metrics: MetricsRegistry

    @classmethod
    def disabled(cls) -> "ObsContext":
        """Tracing off (no-op spans), metrics on — the default wiring."""
        return cls(Tracer(enabled=False), MetricsRegistry())

    @classmethod
    def enabled(cls, clock=None) -> "ObsContext":
        tr = Tracer(enabled=True) if clock is None \
            else Tracer(enabled=True, clock=clock)
        return cls(tr, MetricsRegistry())

    def export(self, out_dir: str) -> dict:
        """Write the standard artifact set under ``out_dir``:
        ``trace.json`` (Chrome trace_event, Perfetto-viewable),
        ``spans.json`` (lossless nested tree the validator consumes),
        ``metrics.prom`` + ``metrics.json`` (registry snapshots).
        Returns {artifact name: path}."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {}
        p = os.path.join(out_dir, "trace.json")
        with open(p, "w") as f:
            json.dump(to_chrome(self.tracer), f)
        paths["trace"] = p
        p = os.path.join(out_dir, "spans.json")
        with open(p, "w") as f:
            json.dump(to_json(self.tracer), f)
        paths["spans"] = p
        p = os.path.join(out_dir, "metrics.prom")
        with open(p, "w") as f:
            f.write(self.metrics.to_prometheus())
        paths["prom"] = p
        p = os.path.join(out_dir, "metrics.json")
        with open(p, "w") as f:
            json.dump(self.metrics.to_json(), f, indent=1)
        paths["metrics"] = p
        return paths
