"""Counters / gauges / fixed-bucket histograms with Prometheus + JSON export.

The operator-facing half of ``repro.obs``: subsystems register named,
labeled metrics into one ``MetricsRegistry`` (the scheduling-policy view
stays on ``sched.TelemetryBus`` — EWMAs the controller plans from; this
registry is the monotonic/queryable view an operator scrapes).

Histograms never retain samples: observations land in fixed log-spaced
buckets (defaults cover 100ns..1000s at ~19% spacing — 4 buckets per
octave), and quantiles are read back by cumulative walk with log-linear
interpolation inside the landing bucket, so p50/p95/p99 are accurate to
bucket resolution on any stream length at O(n_buckets) memory.

``to_prometheus`` emits the text exposition format (counters as
``_total``-style samples, histograms as cumulative ``_bucket{le=...}`` +
``_sum``/``_count``); ``parse_prometheus`` reads it back sample-for-sample
— the round-trip the test suite pins.
"""
from __future__ import annotations

import math
import re
from bisect import bisect_left
from typing import Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_buckets", "parse_prometheus"]


def default_buckets(lo: float = 1e-7, hi: float = 1e3,
                    per_octave: int = 4) -> Tuple[float, ...]:
    """Log-spaced upper bounds from ``lo`` to >= ``hi``: ``per_octave``
    buckets per factor-of-two (4/octave ~= 19% relative resolution)."""
    step = 2.0 ** (1.0 / per_octave)
    bounds = [lo]
    while bounds[-1] < hi:
        bounds.append(bounds[-1] * step)
    return tuple(bounds)


_DEFAULT_BUCKETS = default_buckets()


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: counts per upper bound + (+Inf) overflow.
    No sample retention; quantiles via log-linear interpolation."""
    __slots__ = ("bounds", "counts", "count", "sum")

    def __init__(self, bounds: Optional[Tuple[float, ...]] = None):
        self.bounds = tuple(bounds) if bounds is not None \
            else _DEFAULT_BUCKETS
        self.counts = [0] * (len(self.bounds) + 1)   # last = +Inf
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v

    def quantile(self, q: float) -> float:
        """q in [0, 1]; NaN when empty.  Interpolates log-linearly inside
        the landing bucket (buckets are log-spaced), clamping to the
        bucket's bounds — never off by more than one bucket width."""
        if not self.count:
            return float("nan")
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= rank:
                frac = min(1.0, max(0.0, (rank - seen) / c))
                if i >= len(self.bounds):          # overflow bucket
                    return self.bounds[-1]
                hi = self.bounds[i]
                lo = self.bounds[i - 1] if i else hi / 2.0
                if lo <= 0:
                    return hi * frac
                return math.exp(math.log(lo) +
                                frac * (math.log(hi) - math.log(lo)))
            seen += c
        return self.bounds[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")


_METRIC_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + body + "}"


def _fmt_float(v: float) -> str:
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    return format(v, ".17g")


class MetricsRegistry:
    """Get-or-create registry keyed by (name, sorted labels).  One metric
    name has one type; mixing types under a name raises."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple], object] = {}
        self._types: Dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: dict, **kw):
        prev = self._types.get(name)
        if prev is None:
            self._types[name] = kind
        elif prev != kind:
            raise TypeError(f"metric {name!r} already registered as {prev}")
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = _METRIC_TYPES[kind](**kw)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, buckets: Optional[Tuple[float, ...]]
                  = None, **labels) -> Histogram:
        kw = {} if buckets is None else {"bounds": buckets}
        return self._get("histogram", name, labels, **kw)

    # --- reading ------------------------------------------------------------
    def get(self, name: str, **labels):
        """Existing metric or None (read-only view; no create)."""
        return self._metrics.get((name, _label_key(labels)))

    def value(self, name: str, **labels) -> float:
        m = self.get(name, **labels)
        return 0.0 if m is None else getattr(m, "value", float("nan"))

    def series(self, name: str) -> Dict[Tuple[Tuple[str, str], ...], object]:
        """All (labels -> metric) rows registered under ``name``."""
        return {lk: m for (n, lk), m in self._metrics.items() if n == name}

    def to_json(self) -> dict:
        out: dict = {}
        for (name, lk), m in sorted(self._metrics.items()):
            row: dict = {"labels": dict(lk), "type": self._types[name]}
            if isinstance(m, Histogram):
                row.update(count=m.count, sum=m.sum,
                           p50=m.quantile(0.50), p95=m.quantile(0.95),
                           p99=m.quantile(0.99),
                           buckets={_fmt_float(b): c for b, c in
                                    zip(m.bounds + (math.inf,), m.counts)})
            else:
                row["value"] = m.value
            out.setdefault(name, []).append(row)
        return out

    def to_samples(self) -> Dict[str, float]:
        """Flat Prometheus-shaped samples: ``name{labels}`` -> value.
        Histograms expand to cumulative ``_bucket{le=}`` + _sum/_count —
        exactly what ``parse_prometheus(to_prometheus())`` returns."""
        samples: Dict[str, float] = {}
        for (name, lk), m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                cum = 0
                for b, c in zip(m.bounds + (math.inf,), m.counts):
                    cum += c
                    key = _fmt_labels(tuple(sorted(
                        lk + (("le", _fmt_float(b)),))))
                    samples[f"{name}_bucket{key}"] = float(cum)
                samples[f"{name}_sum{_fmt_labels(lk)}"] = m.sum
                samples[f"{name}_count{_fmt_labels(lk)}"] = float(m.count)
            else:
                samples[f"{name}{_fmt_labels(lk)}"] = float(m.value)
        return samples

    def to_prometheus(self) -> str:
        lines: List[str] = []
        seen_type: set = set()
        for (name, lk), m in sorted(self._metrics.items()):
            if name not in seen_type:
                seen_type.add(name)
                lines.append(f"# TYPE {name} {self._types[name]}")
            if isinstance(m, Histogram):
                cum = 0
                for b, c in zip(m.bounds + (math.inf,), m.counts):
                    cum += c
                    key = _fmt_labels(tuple(sorted(
                        lk + (("le", _fmt_float(b)),))))
                    lines.append(f"{name}_bucket{key} {cum}")
                lines.append(f"{name}_sum{_fmt_labels(lk)} "
                             f"{_fmt_float(m.sum)}")
                lines.append(f"{name}_count{_fmt_labels(lk)} {m.count}")
            else:
                lines.append(f"{name}{_fmt_labels(lk)} "
                             f"{_fmt_float(m.value)}")
        return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r'^(?P<name>[A-Za-z_:][A-Za-z0-9_:]*)'
    r'(?P<labels>\{[^}]*\})?\s+(?P<value>\S+)$')
_LABEL_RE = re.compile(r'([A-Za-z_][A-Za-z0-9_]*)="([^"]*)"')


def parse_prometheus(text: str) -> Dict[str, float]:
    """Text exposition -> ``name{sorted labels}`` -> float.  Labels are
    re-sorted so the keys match ``MetricsRegistry.to_samples`` regardless
    of emission order."""
    samples: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable sample line: {line!r}")
        labels = _LABEL_RE.findall(m.group("labels") or "")
        key = m.group("name") + _fmt_labels(tuple(sorted(labels)))
        raw = m.group("value")
        samples[key] = float("inf") if raw == "+Inf" else float(raw)
    return samples
