"""Trace/metrics artifact validator (the CI obs gate).

    PYTHONPATH=src python -m repro.obs validate --trace-dir obs_out \
        [--ttft-tol 1e-6] [--require-requests 1]

Checks, against the artifact set ``ObsContext.export`` writes:

  * ``spans.json``: every span closed, children inside their parent,
    sequential children sum <= parent duration (``check_span_tree``);
  * every completed ``request`` span's TTFT decomposition:
    ``ttft_s == queue + prefill + insert`` within tolerance, read from the
    span's phase children AND its stamped attributes;
  * ``trace.json`` (Chrome trace_event): rebuilds the span trees from the
    exported artifact and re-verifies the request decomposition on it —
    the file an operator actually opens in Perfetto is the file we gate;
  * ``metrics.prom`` parses, and the admission ledger closes:
    offered == completed + shed when the engine drained.

Exit code 0 = clean; 2 = violations (printed one per line).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.obs.metrics import parse_prometheus
from repro.obs.tracer import (Span, check_span_tree, spans_from_json,
                              tree_from_chrome)


def check_request_ttft(spans: List[Span], tol: float) -> List[str]:
    """TTFT = queue + prefill + insert, per completed generating request.
    Checked two ways: phase-child durations, and the stamped attrs."""
    errs = []
    n_checked = 0
    for root in spans:
        if root.name != "request" or "ttft_s" not in root.attrs:
            continue
        phases = {}
        for c in root.children:
            if c.name in ("queued", "prefill", "insert"):
                phases[c.name] = phases.get(c.name, 0.0) + c.duration
        if set(phases) != {"queued", "prefill", "insert"}:
            errs.append(f"request rid={root.attrs.get('rid')}: missing "
                        f"TTFT phases (have {sorted(phases)})")
            continue
        n_checked += 1
        ttft = float(root.attrs["ttft_s"])
        csum = sum(phases.values())
        if abs(csum - ttft) > tol:
            errs.append(
                f"request rid={root.attrs.get('rid')}: ttft {ttft:.9f}s != "
                f"queued+prefill+insert {csum:.9f}s (|d|="
                f"{abs(csum - ttft):.3e} > {tol:.1e})")
        asum = sum(float(root.attrs.get(k, 0.0))
                   for k in ("queue_s", "prefill_s", "insert_s"))
        if abs(asum - ttft) > tol:
            errs.append(f"request rid={root.attrs.get('rid')}: attr "
                        f"breakdown {asum:.9f}s != ttft {ttft:.9f}s")
    return errs, n_checked


def check_ledger(samples: dict) -> List[str]:
    """offered == completed + shed, read back through the metrics view
    (only meaningful after the engine drained — which the exporting
    drivers guarantee)."""
    offered = samples.get("engine_requests_offered_total")
    if offered is None:
        return []
    completed = samples.get("engine_requests_completed_total", 0.0)
    shed = sum(v for k, v in samples.items()
               if k.startswith("engine_requests_shed_total"))
    if abs(offered - (completed + shed)) > 1e-9:
        return [f"admission ledger leak: offered={offered} != "
                f"completed={completed} + shed={shed}"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("validate", help="check exported trace artifacts")
    v.add_argument("--trace-dir", required=True,
                   help="directory written by ObsContext.export")
    v.add_argument("--ttft-tol", type=float, default=1e-6,
                   help="absolute tolerance (s) for the TTFT decomposition")
    v.add_argument("--require-requests", type=int, default=0,
                   help="fail unless at least N request spans were checked")
    args = ap.parse_args(argv)

    errs: List[str] = []
    spans_path = os.path.join(args.trace_dir, "spans.json")
    with open(spans_path) as f:
        spans = spans_from_json(json.load(f))
    errs += check_span_tree(spans, abs_tol=args.ttft_tol)
    ttft_errs, n_req = check_request_ttft(spans, args.ttft_tol)
    errs += ttft_errs

    chrome_path = os.path.join(args.trace_dir, "trace.json")
    n_chrome = 0
    if os.path.exists(chrome_path):
        with open(chrome_path) as f:
            chrome = tree_from_chrome(json.load(f))
        # µs-granular round-trip: loosen only by the serialization noise
        c_errs, n_chrome = check_request_ttft(chrome,
                                              args.ttft_tol + 1e-5)
        errs += c_errs
    else:
        errs.append(f"missing {chrome_path}")

    prom_path = os.path.join(args.trace_dir, "metrics.prom")
    if os.path.exists(prom_path):
        with open(prom_path) as f:
            samples = parse_prometheus(f.read())
        errs += check_ledger(samples)
    else:
        errs.append(f"missing {prom_path}")

    if n_req < args.require_requests:
        errs.append(f"only {n_req} request spans checked "
                    f"(need >= {args.require_requests})")
    for e in errs:
        print(f"VIOLATION: {e}")
    print(f"checked {sum(1 for _ in spans)} root spans, {n_req} request "
          f"TTFT decompositions (+{n_chrome} via Chrome round-trip): "
          f"{'FAIL' if errs else 'OK'}")
    return 2 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
