"""Span-based tracing: the substrate for Lina's §3 attribution.

A ``Span`` is a named interval on a monotonic clock with attributes and
nested children.  Two usage modes share one tree:

  * context-manager spans (``tracer.span("phase1")``) nest via an explicit
    stack — the step/layer instrumentation in ``runtime.server`` and
    ``runtime.trainer``;
  * manual spans (``tracer.begin`` / ``Span.end_at`` / ``tracer.add``)
    carry explicit timestamps — request lifecycles that cross engine steps
    and live on the *virtual* clock during trace replay.

When the tracer is disabled every entry point returns the shared ``NOOP``
singleton: no ``Span`` is ever allocated, ``with tracer.span(...)`` costs
two no-op method calls, and the disabled fast path is what the 2%-overhead
guard in ``tests/test_obs.py`` measures.  ``tracer.timed`` is the one
always-measuring primitive (it replaces the ad-hoc ``time.perf_counter``
stopwatches the runtime used to carry): the elapsed ``dt`` is functional —
service-time stamps and the phase-2 watchdog depend on it — so it is
measured in both modes, and only the span recording is gated.

Exporters: ``to_json`` (lossless nested tree, what the invariant validator
consumes) and ``to_chrome`` (Chrome ``trace_event`` JSON — open in Perfetto
via ui.perfetto.dev or chrome://tracing; each root span tree gets its own
``tid`` so request lifecycles render as parallel tracks).
``tree_from_chrome`` rebuilds span trees from an exported Chrome trace, so
"TTFT = queue + prefill + insert" stays checkable on the artifact itself.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

__all__ = ["Span", "Tracer", "NOOP", "to_json", "to_chrome",
           "tree_from_chrome", "check_span_tree"]


@dataclass
class Span:
    name: str
    start: float                                   # seconds (tracer clock)
    end: float = float("nan")                      # NaN while still open
    attrs: Dict[str, Any] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)

    @property
    def duration(self) -> float:
        return self.end - self.start

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def child(self, name: str, start: float, end: float, **attrs) -> "Span":
        """Attach a completed child with explicit timestamps."""
        sp = Span(name, float(start), float(end), dict(attrs))
        self.children.append(sp)
        return sp

    def begin_child(self, name: str, start: float, **attrs) -> "Span":
        """Attach an open child (close it with ``end_at``)."""
        sp = Span(name, float(start), attrs=dict(attrs))
        self.children.append(sp)
        return sp

    def end_at(self, end: float, **attrs) -> "Span":
        self.end = float(end)
        self.attrs.update(attrs)
        return self

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str) -> List["Span"]:
        return [s for s in self.walk() if s.name == name]


class _Noop:
    """Disabled-path singleton: satisfies the full Span + context-manager
    API without allocating.  Every mutator returns ``self`` so chained
    instrumentation stays branch-free at call sites."""
    __slots__ = ()
    name = ""
    start = 0.0
    end = 0.0
    duration = 0.0
    attrs: Dict[str, Any] = {}
    children: List["Span"] = []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def child(self, name, start, end, **attrs):
        return self

    def begin_child(self, name, start, **attrs):
        return self

    def end_at(self, end, **attrs):
        return self

    def walk(self):
        return iter(())

    def find(self, name):
        return []


NOOP = _Noop()


class _ActiveSpan:
    """Context manager for stack-nested spans (enabled tracer only)."""
    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.span = Span(name, 0.0, attrs=attrs)

    def __enter__(self) -> Span:
        tr = self._tracer
        sp = self.span
        sp.start = tr.clock()
        if tr._stack:
            tr._stack[-1].children.append(sp)
        else:
            tr._add_root(sp)
        tr._stack.append(sp)
        return sp

    def __exit__(self, *exc):
        tr = self._tracer
        sp = tr._stack.pop()
        sp.end = tr.clock()
        return False


class _Timed:
    """Always-on stopwatch; records a span only when the tracer is enabled.
    Use where the measured ``dt`` is functional (service-time stamps, the
    phase-2 watchdog), so disabling tracing cannot change behavior.
    ``record=False`` keeps just the stopwatch — for call sites that lay
    their own explicit-timestamp spans out afterwards (engine step phases
    live on the virtual clock, not the wall clock being measured here)."""
    __slots__ = ("_tracer", "_name", "_attrs", "_record", "t0", "dt")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict,
                 record: bool = True):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._record = record
        self.t0 = 0.0
        self.dt = 0.0

    def __enter__(self) -> "_Timed":
        self.t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc):
        tr = self._tracer
        self.dt = tr.clock() - self.t0
        if self._record and tr.enabled:
            tr.add(self._name, self.t0, self.t0 + self.dt, **self._attrs)
        return False


class Tracer:
    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 max_roots: int = 200_000):
        self.enabled = enabled
        self.clock = clock
        self.roots: List[Span] = []
        self.dropped_roots = 0        # no silent caps: overflow is counted
        self._stack: List[Span] = []
        self._max_roots = max_roots

    # --- recording ----------------------------------------------------------
    def span(self, name: str, **attrs):
        """Stack-nested span context manager (no-op when disabled)."""
        if not self.enabled:
            return NOOP
        return _ActiveSpan(self, name, attrs)

    def timed(self, name: str, record: bool = True, **attrs) -> _Timed:
        """Stopwatch that ALWAYS measures (``.dt`` after exit) and records
        a span only when enabled (and ``record`` is left on)."""
        return _Timed(self, name, attrs, record=record)

    def begin(self, name: str, start: Optional[float] = None, **attrs):
        """Open a manual root span (explicit-timestamp mode; not stack
        nested).  Close with ``span.end_at(t)``."""
        if not self.enabled:
            return NOOP
        sp = Span(name, self.clock() if start is None else float(start),
                  attrs=dict(attrs))
        self._add_root(sp)
        return sp

    def add(self, name: str, start: float, end: float, **attrs):
        """Record a completed span with explicit timestamps — nested under
        the innermost open context-manager span if there is one, else as a
        new root."""
        if not self.enabled:
            return NOOP
        sp = Span(name, float(start), float(end), dict(attrs))
        if self._stack:
            self._stack[-1].children.append(sp)
        else:
            self._add_root(sp)
        return sp

    def _add_root(self, sp: Span) -> None:
        if len(self.roots) >= self._max_roots:
            self.dropped_roots += 1
            return
        self.roots.append(sp)

    def clear(self) -> None:
        self.roots = []
        self._stack = []
        self.dropped_roots = 0


# --- exporters --------------------------------------------------------------
def _span_dict(sp: Span) -> dict:
    return {"name": sp.name, "start": sp.start, "end": sp.end,
            "attrs": sp.attrs,
            "children": [_span_dict(c) for c in sp.children]}


def _span_from_dict(d: dict) -> Span:
    sp = Span(d["name"], float(d["start"]), float(d["end"]),
              dict(d.get("attrs") or {}))
    sp.children = [_span_from_dict(c) for c in d.get("children", ())]
    return sp


def to_json(tracer: Tracer) -> dict:
    return {"dropped_roots": tracer.dropped_roots,
            "spans": [_span_dict(r) for r in tracer.roots]}


def spans_from_json(doc: dict) -> List[Span]:
    return [_span_from_dict(d) for d in doc.get("spans", ())]


def to_chrome(tracer: Tracer) -> dict:
    """Chrome ``trace_event`` format: complete ("X") events, µs
    timestamps rebased to the earliest span so virtual-clock and
    wall-clock trees share a viewable origin.  One ``tid`` per root tree
    keeps nesting unambiguous (Perfetto nests by containment per track)."""
    events = []
    t0 = min((r.start for r in tracer.roots), default=0.0)
    for tid, root in enumerate(tracer.roots):
        for sp in root.walk():
            end = sp.end if sp.end == sp.end else sp.start   # open: zero-dur
            events.append({
                "name": sp.name, "ph": "X", "pid": 0, "tid": tid,
                "ts": (sp.start - t0) * 1e6,
                "dur": max(0.0, (end - sp.start)) * 1e6,
                "args": {k: v for k, v in sp.attrs.items()},
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def tree_from_chrome(doc: dict) -> List[Span]:
    """Rebuild span trees from a Chrome trace export (timestamps come back
    in seconds relative to the export origin).  Events on one ``tid`` nest
    by interval containment — exactly how ``to_chrome`` laid them out."""
    by_tid: Dict[Any, List[dict]] = {}
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "X":
            by_tid.setdefault(ev.get("tid", 0), []).append(ev)
    roots: List[Span] = []
    eps = 1e-9
    for tid in sorted(by_tid):
        evs = sorted(by_tid[tid], key=lambda e: (e["ts"], -e["dur"]))
        stack: List[Span] = []
        for ev in evs:
            sp = Span(ev["name"], ev["ts"] * 1e-6,
                      (ev["ts"] + ev["dur"]) * 1e-6,
                      dict(ev.get("args") or {}))
            while stack and sp.start > stack[-1].end - eps:
                stack.pop()
            if stack:
                stack[-1].children.append(sp)
            else:
                roots.append(sp)
            stack.append(sp)
    return roots


def write_chrome(tracer: Tracer, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome(tracer), f)


# --- invariants -------------------------------------------------------------
def check_span_tree(spans: List[Span], rel_tol: float = 1e-6,
                    abs_tol: float = 1e-6) -> List[str]:
    """Structural invariants every exported trace must satisfy; returns a
    list of violation strings (empty = clean).

      * every span is closed and non-negative;
      * children lie inside their parent's interval;
      * the children of one span, being sequential phases, sum to at most
        the parent's duration.
    """
    errs: List[str] = []
    for root in spans:
        for sp in root.walk():
            if sp.end != sp.end:
                errs.append(f"open span: {sp.name}")
                continue
            if sp.end < sp.start - abs_tol:
                errs.append(f"negative span: {sp.name} "
                            f"({sp.start}..{sp.end})")
            csum = 0.0
            for c in sp.children:
                if c.start < sp.start - abs_tol or \
                        (c.end == c.end and c.end > sp.end + abs_tol):
                    errs.append(f"child {c.name} escapes parent {sp.name}")
                csum += max(0.0, c.duration)
            budget = sp.duration * (1.0 + rel_tol) + abs_tol
            if csum > budget:
                errs.append(f"children of {sp.name} sum to {csum:.9f}s > "
                            f"parent {sp.duration:.9f}s")
    return errs
