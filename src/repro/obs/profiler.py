"""Device-time attribution: jax.profiler hooks + overlap-phase replay.

Two pieces:

  * ``trace_session`` / ``StepProfiler`` — optional ``jax.profiler`` trace
    capture around N steps, guarded so CPU CI (and builds without
    tensorboard_plugin_profile) degrade to a no-op instead of failing.
    The captured TensorBoard trace is where the fwd/bwd device-time split
    inside a jitted train step actually lives; the host-side spans around
    it (``runtime.trainer``) carry the schedule attribution.

  * ``attribute_overlap`` — replays the overlap microbench's measured
    phases (per-variant serial baseline, a2a-only reference, pipelined
    time; ``benchmarks.train_side`` rows / the ``overlap`` key of
    ``BENCH_schedules.json``) into a span tree, so "fraction of the a2a
    hidden" becomes a quantity recomputable FROM THE TRACE
    (``hidden_fraction``) instead of a bench-only number.  The identity
    pinned by tests: for every row,
    ``hidden_fraction(attribute_overlap(...)) == row["a2a_hidden_frac"]``
    within float tolerance, surviving a Chrome-trace export round-trip.
"""
from __future__ import annotations

from typing import List, Optional

from repro.obs.tracer import Span, Tracer

__all__ = ["trace_session", "StepProfiler", "attribute_overlap",
           "hidden_fraction"]


class trace_session:
    """Context manager around ``jax.profiler.start_trace`` /
    ``stop_trace``.  ``active`` reports whether a device trace is actually
    being captured — False on import/start failure (CPU CI keeps running,
    the host-side span tracer is unaffected)."""

    def __init__(self, logdir: Optional[str], enabled: bool = True):
        self.logdir = logdir
        self.enabled = enabled and logdir is not None
        self.active = False

    def __enter__(self) -> "trace_session":
        if not self.enabled:
            return self
        try:
            import jax
            jax.profiler.start_trace(self.logdir)
            self.active = True
        except Exception:
            self.active = False
        return self

    def __exit__(self, *exc):
        if self.active:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass
            self.active = False
        return False


class StepProfiler:
    """Start a jax.profiler trace at step ``start`` and stop it after
    ``steps`` profiled steps — the usual "skip compile, profile a window"
    shape.  Drive it with ``on_step(step_idx)`` from any loop."""

    def __init__(self, logdir: Optional[str], start: int = 2,
                 steps: int = 3, enabled: bool = True):
        self.start = int(start)
        self.stop_at = int(start) + int(steps)
        self._session = trace_session(logdir, enabled=enabled)
        self._started = False

    @property
    def active(self) -> bool:
        return self._session.active

    def on_step(self, step: int) -> None:
        if not self._started and step >= self.start:
            self._started = True
            self._session.__enter__()
        if self._session.active and step >= self.stop_at:
            self._session.__exit__()

    def close(self) -> None:
        self._session.__exit__()


def attribute_overlap(tracer: Tracer, rows, t0: float = 0.0) -> List:
    """Replay overlap-microbench rows into spans.

    Each row (a dict with ``variant``, ``chunks_requested``,
    ``chunks_chosen``, ``us_per_call``, ``serial_us``, ``a2a_us``,
    ``a2a_hidden_frac`` — the schema of ``BENCH_schedules.json``'s
    ``overlap`` key) becomes one root span with three sequential phase
    children::

        overlap/<variant>-c<requested>
          ├─ serial      (pipeline-off baseline, serial_us)
          ├─ a2a_only    (chunked dispatch+combine with identity expert)
          └─ pipelined   (the overlapped variant, us_per_call)

    Spans are laid out back-to-back from ``t0`` on a microsecond-scaled
    timeline.  Returns the created root spans (empty when disabled)."""
    roots = []
    cursor = float(t0)
    for row in rows:
        ser = float(row["serial_us"]) * 1e-6
        a2a = float(row["a2a_us"]) * 1e-6
        pipe = float(row["us_per_call"]) * 1e-6
        name = (f"overlap/{row['variant']}"
                f"-c{row.get('chunks_requested', '?')}")
        root = tracer.add(name, cursor, cursor + ser + a2a + pipe,
                          **{k: row[k] for k in
                             ("mode", "variant", "chunks_requested",
                              "chunks_chosen", "a2a_hidden_frac")
                             if k in row})
        t = cursor
        root.child("serial", t, t + ser)
        t += ser
        root.child("a2a_only", t, t + a2a)
        t += a2a
        root.child("pipelined", t, t + pipe)
        cursor += ser + a2a + pipe
        roots.append(root)
    return roots


def hidden_fraction(span: Span) -> float:
    """Recompute the overlap efficiency from an attribution span's phase
    children: ``(serial - pipelined) / a2a_only``, clipped to [0, 1] —
    the same formula ``benchmarks.train_side`` measures, but sourced from
    the (possibly Chrome-round-tripped) trace."""
    dur = {}
    for c in span.children:
        dur[c.name] = c.duration
    a2a = dur.get("a2a_only", 0.0)
    if a2a <= 0:
        return 0.0
    frac = (dur.get("serial", 0.0) - dur.get("pipelined", 0.0)) / a2a
    return max(0.0, min(1.0, frac))
