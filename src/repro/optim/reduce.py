"""Lina §4 gradient-reduction subsystem: the DP-axis reduce as an explicit,
schedulable collective instead of whatever XLA's partitioner happens to emit.

The paper's training-side rule is *all-to-all goes first*: the gradient
allreduce that runs concurrently with the backward a2a must yield link
bandwidth to it (Figs. 5/7), and to make yielding cheap both are tensor-
partitioned into uniform micro-ops (Fig. 8).  Under SPMD the whole step is a
static program, so "priority" becomes *program order*: every reduce micro-op
carries a compile-time dependency edge on the backward-a2a completion token
(``core.microop.ordered_after``), which XLA cannot hoist above the a2a.

Five schedules (the same names ``benchmarks/commmodel.simulate_step`` models
analytically, so measured and simulated rows line up):

  ``baseline``                      one fused psum of the whole flattened
                                    gradient vector, no ordering edge —
                                    the DDP default (Fig. 7a).
  ``priority``                      same single op, but ordered after the
                                    backward-a2a token (Fig. 7b).
  ``fixed``                         Fig. 7c: the whole-tensor reduce
                                    *deferred past the second backward a2a*
                                    of the MoE layers.  Under SPMD program
                                    order this compiles to the same single
                                    ordered op as ``priority`` — the token
                                    already pins the reduce after every
                                    backward (and forward) a2a — so its
                                    measured row is the sanity anchor for
                                    the analytic model, where the two
                                    differ only through preemption of an
                                    in-flight allreduce (which a static
                                    SPMD program cannot express).
  ``priority+partition``            uniform micro-op chunks sized by
                                    ``partition_bytes``, each ordered after
                                    the token and chained among themselves
                                    (Fig. 8a).
  ``priority+partition+pipeline``   chunked reduce issued *per microbatch*
                                    inside the unrolled gradient-accumulation
                                    scan, so chunk k of microbatch i can
                                    overlap microbatch i+1's compute
                                    (Fig. 8b).  The per-call behavior here is
                                    identical to ``priority+partition``; the
                                    interleaving lives in
                                    ``launch.steps.make_train_step``.

Optional compression (``optim.compression``) wraps the chunked reduce:
``bf16`` halves wire bytes with a cast (the psum payload really is bf16),
``int8_ef`` quantizes with an error-feedback residual carried across steps
(``init_reduce_state`` / ``ReduceState``).  Note the int8 path reproduces
the *numerics* (quantize → sum → dequantize, EF residual), not the wire
width: the psum payload is int32 so dp-many summands cannot overflow — a
real deployment would use an int8 ring-reduce with wider accumulators.
Both preserve the ordering edges — compression composes with, never
replaces, the schedule.

All schedules are numerically mean-psum reductions: gradients enter
replicated over dp (the jit-level autodiff already produced the global
gradient), so the explicit collective is an identity *value*-wise while the
wire traffic, chunking, and ordering are real — exactly what the measured
ablation in ``benchmarks/train_side.py`` times.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import axes, microop
from repro.optim.compression import (Int8State, compress_int8_ef,
                                     init_int8_state)

SCHEDULES = ("baseline", "priority", "fixed", "priority+partition",
             "priority+partition+pipeline")
COMPRESSIONS = (None, "bf16", "int8_ef")

# Fig. 15: 30MB micro-ops sit in the flat bottom of the partition-size sweep
DEFAULT_PARTITION_BYTES = 30e6


@dataclass(frozen=True)
class ReduceConfig:
    schedule: str = "baseline"
    partition_bytes: float = DEFAULT_PARTITION_BYTES
    compression: Optional[str] = None     # None | "bf16" | "int8_ef"

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"expected one of {SCHEDULES}")
        if self.compression not in COMPRESSIONS:
            raise ValueError(f"unknown compression {self.compression!r}; "
                             f"expected one of {COMPRESSIONS}")

    @property
    def ordered(self) -> bool:
        return self.schedule != "baseline"

    @property
    def partitioned(self) -> bool:
        return "partition" in self.schedule


class ReduceState(NamedTuple):
    """Cross-step reducer state (today: the int8-EF residual)."""
    int8: Optional[Int8State]


def init_reduce_state(params, cfg: ReduceConfig) -> Optional[ReduceState]:
    """Per-parameter reducer state, or None when the reducer is stateless."""
    if cfg.compression == "int8_ef":
        return ReduceState(init_int8_state(params))
    return None


def n_chunks_for_bytes(grads, partition_bytes: float) -> int:
    """Uniform micro-op count for the flattened gradient vector (§4.2: no
    gradient-boundary bucketing — pure tensor partitioning)."""
    total = sum(l.size * jnp.dtype(l.dtype).itemsize
                for l in jax.tree.leaves(grads))
    return max(1, math.ceil(total / max(float(partition_bytes), 1.0)))


def reduce_axes(mesh) -> tuple:
    """The DP mesh axes the gradient reduction runs over."""
    return axes.dp_axes(mesh)


# ---------------------------------------------------------------------------
# the per-device reduction body (runs inside shard_map)
# ---------------------------------------------------------------------------

def _reduce_shard(grads, int8_state, after, *, axes, cfg: ReduceConfig,
                  n_chunks: int):
    """Reduce (mean) ``grads`` over ``axes`` under schedule ``cfg``.

    Runs per-device.  Returns (reduced_grads, new_int8_state).  The int8
    path assumes gradients enter replicated over ``axes`` (true for this
    repo's train step), so each device's quantization scale agrees and the
    integer psum-mean dequantizes exactly like a local dequantize.
    """
    tok = after if cfg.ordered else None
    if cfg.compression == "bf16":
        g16 = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        red = microop.prioritized_chunked_reduce(g16, axes, n_chunks,
                                                 after=tok, mean=True)
        red = jax.tree.map(lambda r, g: r.astype(g.dtype), red, grads)
        return red, int8_state
    if cfg.compression == "int8_ef":
        (qs, scales), new_state = compress_int8_ef(grads, int8_state)
        # sum in int32 (dp-many values in [-127,127] cannot overflow) and
        # dequantize with the shared scale — int8-EF numerics, though the
        # psum payload itself stays 4B/element (see module docstring)
        q32 = jax.tree.map(lambda q: q.astype(jnp.int32), qs)
        summed = microop.prioritized_chunked_reduce(q32, axes, n_chunks,
                                                    after=tok, mean=False)
        denom = 1
        for a in axes:
            denom *= lax.psum(1, a)
        red = jax.tree.map(
            lambda s, sc, g: (s.astype(jnp.float32) * sc / denom
                              ).astype(g.dtype),
            summed, scales, grads)
        return red, new_state
    red = microop.prioritized_chunked_reduce(grads, axes, n_chunks,
                                             after=tok, mean=True)
    return red, int8_state


# ---------------------------------------------------------------------------
# top-level entry: global grads -> shard_map -> reduced global grads
# ---------------------------------------------------------------------------

def reduce_gradients(mesh, grads, cfg: ReduceConfig, *,
                     after: Optional[jax.Array] = None,
                     state: Optional[ReduceState] = None):
    """Explicit DP-axis gradient reduction under Lina's schedule.

    mesh:   the training mesh (None -> the 1-device default mesh, where the
            collectives are trivial but the schedule still traces/compiles).
    grads:  the global gradient pytree out of jit-level autodiff.
    after:  backward-a2a completion token (see ``backward_a2a_token``);
            ignored by ``baseline``.
    state:  ``ReduceState`` for int8-EF, else None.

    Returns (reduced_grads, new_state).
    """
    if mesh is None:
        from repro.core.moe import default_mesh
        mesh = default_mesh()
    axes = tuple(a for a in reduce_axes(mesh) if a in mesh.axis_names)
    n_chunks = (n_chunks_for_bytes(grads, cfg.partition_bytes)
                if cfg.partitioned else 1)
    if after is None:
        after = jnp.zeros((), jnp.float32)
    int8_state = state.int8 if (state is not None and
                                cfg.compression == "int8_ef") else None
    if cfg.compression == "int8_ef" and int8_state is None:
        raise ValueError("schedule with int8_ef compression needs a "
                         "ReduceState (see init_reduce_state)")

    body = partial(_reduce_shard, axes=axes, cfg=cfg, n_chunks=n_chunks)
    rep = jax.tree.map(lambda _: P(), grads)
    st_spec = jax.tree.map(lambda _: P(), int8_state)
    red, new_int8 = shard_map(
        body, mesh=mesh,
        in_specs=(rep, st_spec, P()),
        out_specs=(rep, st_spec),
        check_rep=False,
    )(grads, int8_state, after)
    new_state = ReduceState(new_int8) if new_int8 is not None else state
    return red, new_state


def backward_a2a_token(grads, fwd_marker: Optional[jax.Array] = None):
    """The backward-a2a completion marker for ``after=``.

    Under SPMD the backward all-to-all's completion is observable as a data
    dependency: every expert-weight gradient leaf is computed *from tokens
    received over the backward a2a*, so a zero-valued scalar derived from
    those leaves is available exactly when the a2a has completed.  The
    forward marker threaded out of ``core.moe`` (``MoEOutput.a2a_token`` →
    ``ModelOutput.a2a_marker``) is folded in as well, pinning the reduce
    after the forward a2a micro-ops too.

    Returns None when the gradient tree has no MoE leaves and no marker was
    given (dense model: nothing to yield to).
    """
    from repro.core.moe import MoEParams
    nodes = jax.tree.leaves(grads,
                            is_leaf=lambda x: isinstance(x, MoEParams))
    moe_leaves = [l for n in nodes if isinstance(n, MoEParams)
                  for l in jax.tree.leaves(n)]
    if not moe_leaves and fwd_marker is None:
        return None
    tok = jnp.zeros((), jnp.float32)
    for l in moe_leaves:
        tok = tok + microop._token_of(l)     # single-sourced marker idiom
    if fwd_marker is not None:
        tok = tok + microop._token_of(fwd_marker)
    return tok
