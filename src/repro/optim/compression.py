"""Gradient compression for the DP reduction (cross-pod links are the
scarcest resource at 1000+ nodes): bf16 cast and int8 with error feedback.

Consumed by ``optim.reduce`` (``ReduceConfig.compression``), which wraps
the compressed payload around ``prioritized_chunked_reduce`` so Lina's
a2a-priority ordering is preserved, and surfaced as
``TrainerConfig.grad_compression`` / ``make_train_step(grad_compression=)``.
The int8 error-feedback residual (``Int8State``) is carried across steps as
the trainer's ``reduce_state`` and rides in checkpoints, so resume stays
bitwise.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def compress_bf16(tree):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree)


def decompress_bf16(tree, like):
    return jax.tree.map(lambda g, p: g.astype(p.dtype), tree, like)


class Int8State(NamedTuple):
    """Error-feedback residual (one per gradient leaf)."""
    residual: Any


def init_int8_state(params) -> Int8State:
    return Int8State(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_int8_ef(grads, state: Int8State):
    """Error-feedback int8: quantize (g + residual), carry the error.
    Returns ((q_int8, scales), new_state)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        err = g32 - q.astype(jnp.float32) * scale
        return q, scale, err

    qs = jax.tree.map(lambda g, r: one(g, r)[0], grads, state.residual)
    scales = jax.tree.map(lambda g, r: one(g, r)[1], grads, state.residual)
    errs = jax.tree.map(lambda g, r: one(g, r)[2], grads, state.residual)
    return (qs, scales), Int8State(errs)


def decompress_int8(qs, scales, like=None):
    out = jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)
    if like is not None:
        out = jax.tree.map(lambda g, p: g.astype(p.dtype), out, like)
    return out
