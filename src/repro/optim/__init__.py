"""Optimizer substrate: AdamW with mesh-sharded states, cosine schedule,
global-norm clipping, and gradient compression for the DP axis."""
from repro.optim.adamw import (AdamWConfig, OptState, init_opt_state,
                               adamw_update, cosine_schedule,
                               clip_by_global_norm)
from repro.optim.compression import (compress_bf16, decompress_bf16,
                                     Int8State, compress_int8_ef,
                                     decompress_int8)
from repro.optim.reduce import (SCHEDULES, ReduceConfig, ReduceState,
                                backward_a2a_token, init_reduce_state,
                                n_chunks_for_bytes, reduce_gradients)
