"""AdamW with states that mirror the parameter sharding (states are created
`like` the params, so pjit shards m/v exactly as the FSDP'd weights — ZeRO
for free), plus cosine LR schedule and global-norm clipping."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params))


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_schedule(step, cfg)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    # three passes (XLA CSEs the shared subexpressions); avoids tuple-leaf
    # ambiguity with NamedTuple param nodes
    new_params = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[0],
                              params, grads, state.m, state.v)
    new_m = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[1],
                         params, grads, state.m, state.v)
    new_v = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[2],
                         params, grads, state.m, state.v)
    return new_params, OptState(step, new_m, new_v), {"grad_norm": gn, "lr": lr}
