"""Finding model + baseline workflow for the static contract checker.

A finding is identified by a *fingerprint* — ``category:module:qualname:key``
— that deliberately excludes line numbers and byte counts, so reformatting a
file or nudging a block size does not churn the baseline.  CI compares the
current findings against the committed ``ANALYSIS_BASELINE.json`` and fails
only on fingerprints not present there: known ceilings stay tracked (and
visible in the report) without blocking the build, while any *new* contract
violation does.
"""
from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass
class Finding:
    category: str          # e.g. "vmem-over-budget", "unbound-axis"
    module: str            # repo-relative path, e.g. "src/repro/kernels/dispatch.py"
    qualname: str          # enclosing function / kernel entry point
    key: str               # stable discriminator (block name, shape case, ...)
    message: str           # human-readable, with the computed numbers
    severity: str = "error"      # "error" | "warning"
    lineno: int | None = None    # informational only — not fingerprinted
    data: dict = dataclasses.field(default_factory=dict)

    @property
    def fingerprint(self) -> str:
        return f"{self.category}:{self.module}:{self.qualname}:{self.key}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


def sort_findings(findings: list[Finding]) -> list[Finding]:
    return sorted(findings, key=lambda f: (f.category, f.module,
                                           f.qualname, f.key))


def report_dict(findings: list[Finding], *, budget: int | None = None) -> dict:
    by_cat: dict[str, int] = {}
    for f in findings:
        by_cat[f.category] = by_cat.get(f.category, 0) + 1
    return {
        "version": 1,
        "vmem_budget_bytes": budget,
        "counts": dict(sorted(by_cat.items())),
        "findings": [f.to_dict() for f in sort_findings(findings)],
    }


def write_baseline(path: str, findings: list[Finding]) -> None:
    """Baseline = the fingerprint set (plus messages for readability)."""
    payload = {
        "version": 1,
        "fingerprints": {f.fingerprint: f.message
                         for f in sort_findings(findings)},
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")


def load_baseline(path: str) -> set[str]:
    with open(path) as fh:
        payload = json.load(fh)
    return set(payload.get("fingerprints", {}))


def new_findings(findings: list[Finding], baseline: set[str]) -> list[Finding]:
    return [f for f in findings if f.fingerprint not in baseline]
