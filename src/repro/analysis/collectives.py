"""Pass 2 — mesh-collective contracts over ``src/repro/``.

Three AST lints:

* ``axis-literal`` — axis-name string literals ("data", "model", ...)
  anywhere outside ``repro/core/axes.py`` (docstrings exempt).  All axis
  names must come from the one constants module, so a typo is an
  ImportError/NameError instead of a silently-unbound collective.

* ``unbound-axis`` — every ``lax.psum`` / ``all_to_all`` / ``axis_index``
  ... axis argument that the resolver can evaluate statically must name a
  canonical mesh axis (``repro.core.axes.MESH_AXES``).  Resolution follows
  constants, tuples, ``axes.X`` attributes, imported axes names, local /
  module assignments, and function parameters through their in-module call
  sites (including ``functools.partial``), to a small depth.  Expressions
  that stay dynamic (e.g. ``mesh.axis_names``-derived tuples) are skipped —
  combined with the ``axis-literal`` rule they can only ever carry
  canonical values, which is the invariant this pass enforces.

* ``dropped-ordering-token`` — results of token-producing calls
  (``pipelined_expert_ffn``-style ``(value, a2a_token)`` pairs) where the
  ordering token is discarded: the whole call as a bare expression
  statement, or a tuple-unpack whose token target is ``_``/never read.
  Dropping the token silently un-orders the backward all-to-all against
  the DP reduce (the §4 priority schedule).
"""
from __future__ import annotations

import ast
import os

from repro.analysis.findings import Finding
from repro.core import axes as _axes_mod

AXES_MODULE = "repro.core.axes"

# collective -> positional index of the axis-name argument
COLLECTIVES = {
    "psum": 1, "pmean": 1, "pmax": 1, "pmin": 1, "psum_scatter": 1,
    "all_to_all": 1, "all_gather": 1, "ppermute": 1,
    "axis_index": 0, "axis_size": 0,
}
_AXIS_KWARG = "axis_name"

# producer function name -> index of the ordering token in its result tuple
TOKEN_PRODUCERS = {"pipelined_expert_ffn": 1}

_MAX_DEPTH = 3


def canonical_axes() -> set:
    """All scalar axis names exported by repro.core.axes."""
    vals = set()
    for name in dir(_axes_mod):
        if not name.isupper():
            continue
        v = getattr(_axes_mod, name)
        if isinstance(v, str):
            vals.add(v)
        elif isinstance(v, tuple):
            vals.update(x for x in v if isinstance(x, str))
    return vals


def _axes_constants() -> dict:
    return {name: getattr(_axes_mod, name) for name in dir(_axes_mod)
            if name.isupper()}


# ------------------------------------------------------------ module map --

class _ModuleInfo:
    """Per-file symbol tables the resolver consults."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.module_assigns: dict[str, ast.expr] = {}
        self.axes_aliases: set[str] = set()       # `axes`, `ax`, ...
        self.imported_axes: dict[str, object] = {}  # EP_AXIS -> "model"
        self.functions: dict[str, ast.FunctionDef] = {}
        consts = _axes_constants()
        for node in tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self.module_assigns[node.targets[0].id] = node.value
            elif isinstance(node, ast.ImportFrom):
                if node.module == AXES_MODULE:
                    for a in node.names:
                        if a.name in consts:
                            self.imported_axes[a.asname or a.name] = \
                                consts[a.name]
                elif node.module == "repro.core":
                    for a in node.names:
                        if a.name == "axes":
                            self.axes_aliases.add(a.asname or "axes")
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == AXES_MODULE:
                        self.axes_aliases.add(a.asname or "repro")
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                self.functions[node.name] = node


def _docstring_nodes(tree: ast.Module) -> set:
    ids = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.FunctionDef,
                             ast.AsyncFunctionDef, ast.ClassDef)):
            body = node.body
            if body and isinstance(body[0], ast.Expr) \
                    and isinstance(body[0].value, ast.Constant) \
                    and isinstance(body[0].value.value, str):
                ids.add(id(body[0].value))
    return ids


# -------------------------------------------------------------- resolver --

class _Unknown(Exception):
    pass


def _local_assigns(fn: ast.FunctionDef) -> dict:
    out = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            out[node.targets[0].id] = node.value
    return out


def _param_default(fn: ast.FunctionDef, name: str):
    args = fn.args
    pos = args.posonlyargs + args.args
    n_def = len(args.defaults)
    for i, a in enumerate(pos):
        if a.arg == name and i >= len(pos) - n_def:
            return args.defaults[i - (len(pos) - n_def)]
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if a.arg == name and d is not None:
            return d
    return None


def _param_index(fn: ast.FunctionDef, name: str) -> int | None:
    pos = fn.args.posonlyargs + fn.args.args
    for i, a in enumerate(pos):
        if a.arg == name:
            return i
    return None


def _is_param(fn: ast.FunctionDef, name: str) -> bool:
    args = fn.args
    return any(a.arg == name for a in
               args.posonlyargs + args.args + args.kwonlyargs)


def _callsite_exprs(info: _ModuleInfo, fn_name: str, param: str,
                    param_idx: int | None):
    """(caller_fn_or_None, expr) pairs binding ``param`` at each in-module
    call of ``fn_name`` — direct calls and functools.partial."""
    out = []
    for caller in [None] + list(info.functions.values()):
        body = info.tree if caller is None else caller
        for node in ast.walk(body):
            if not isinstance(node, ast.Call):
                continue
            callee, args, kwargs = None, node.args, node.keywords
            f = node.func
            if isinstance(f, ast.Name) and f.id == fn_name:
                callee = fn_name
            elif isinstance(f, ast.Attribute) and f.attr == fn_name:
                callee = fn_name
            elif (isinstance(f, ast.Name) and f.id == "partial"
                  or isinstance(f, ast.Attribute) and f.attr == "partial"):
                if args and ((isinstance(args[0], ast.Name)
                              and args[0].id == fn_name)
                             or (isinstance(args[0], ast.Attribute)
                                 and args[0].attr == fn_name)):
                    callee, args = fn_name, args[1:]
                    param_idx_here = None  # partial: keywords only
                else:
                    continue
            if callee is None:
                continue
            bound = None
            for kw in kwargs:
                if kw.arg == param:
                    bound = kw.value
            if bound is None and param_idx is not None \
                    and not (isinstance(f, (ast.Name, ast.Attribute))
                             and getattr(f, "id", getattr(f, "attr", ""))
                             == "partial") \
                    and param_idx < len(args):
                bound = args[param_idx]
            if bound is not None:
                out.append((caller, bound))
    return out


def _resolve(expr, info: _ModuleInfo, fn: ast.FunctionDef | None,
             depth: int = 0) -> list:
    """Evaluate an axis expression to its list of axis-name strings.
    Raises _Unknown for anything dynamic."""
    if depth > _MAX_DEPTH:
        raise _Unknown
    if isinstance(expr, ast.Constant):
        if isinstance(expr.value, str):
            return [expr.value]
        raise _Unknown
    if isinstance(expr, (ast.Tuple, ast.List)):
        vals = []
        for e in expr.elts:
            vals.extend(_resolve(e, info, fn, depth + 1))
        return vals
    if isinstance(expr, ast.Attribute) \
            and isinstance(expr.value, ast.Name) \
            and expr.value.id in info.axes_aliases:
        v = _axes_constants().get(expr.attr)
        if isinstance(v, str):
            return [v]
        if isinstance(v, tuple):
            return list(v)
        raise _Unknown
    if isinstance(expr, ast.Name):
        name = expr.id
        if fn is not None:
            local = _local_assigns(fn)
            if name in local:
                return _resolve(local[name], info, fn, depth + 1)
            default = _param_default(fn, name)
            if default is not None:
                return _resolve(default, info, fn, depth + 1)
            if _is_param(fn, name):
                sites = _callsite_exprs(info, fn.name, name,
                                        _param_index(fn, name))
                if not sites:
                    raise _Unknown
                vals = []
                for caller, bound in sites:
                    vals.extend(_resolve(bound, info, caller, depth + 1))
                return vals
        if name in info.imported_axes:
            v = info.imported_axes[name]
            return list(v) if isinstance(v, tuple) else [v]
        if name in info.module_assigns:
            return _resolve(info.module_assigns[name], info, None, depth + 1)
    raise _Unknown


# --------------------------------------------------------------- checks ---

def _collective_name(node: ast.Call) -> str | None:
    f = node.func
    name = f.attr if isinstance(f, ast.Attribute) else \
        (f.id if isinstance(f, ast.Name) else None)
    return name if name in COLLECTIVES else None


def _axis_arg(node: ast.Call, name: str):
    for kw in node.keywords:
        if kw.arg == _AXIS_KWARG:
            return kw.value
    idx = COLLECTIVES[name]
    return node.args[idx] if idx < len(node.args) else None


def _check_collectives(rel: str, info: _ModuleInfo, canon: set) -> list:
    findings = []
    containers = [(None, info.tree)] + \
        [(f, f) for f in info.functions.values()]
    seen_calls: set[int] = set()
    for fn, body in containers:
        for node in ast.walk(body):
            if not isinstance(node, ast.Call) or id(node) in seen_calls:
                continue
            name = _collective_name(node)
            if name is None:
                continue
            # attribute innermost functions to themselves, not enclosing fns
            owner = fn
            for g in info.functions.values():
                if g is not body and any(n is node for n in ast.walk(g)):
                    owner = g
            if owner is not fn:
                continue
            seen_calls.add(id(node))
            axis_expr = _axis_arg(node, name)
            if axis_expr is None:
                continue
            try:
                vals = _resolve(axis_expr, info, fn)
            except _Unknown:
                continue
            bad = sorted(set(v for v in vals if v not in canon))
            if bad:
                findings.append(Finding(
                    "unbound-axis", rel,
                    fn.name if fn is not None else "<module>",
                    f"{name}:{','.join(bad)}",
                    f"{name} at {rel}:{node.lineno} uses axis name(s) "
                    f"{bad} not bound by any canonical mesh axis "
                    f"(repro.core.axes.MESH_AXES = {sorted(canon)})",
                    lineno=node.lineno))
    return findings


def _check_axis_literals(rel: str, tree: ast.Module, canon: set) -> list:
    doc_ids = _docstring_nodes(tree)
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and node.value in canon and id(node) not in doc_ids:
            findings.append(Finding(
                "axis-literal", rel, "<module>",
                f"{node.value}@L{0}",
                f'axis name "{node.value}" appears as a string literal at '
                f"{rel}:{node.lineno} — import it from repro.core.axes "
                f"instead so typos fail at import time",
                lineno=node.lineno))
    # collapse duplicates of the same literal value per module
    uniq: dict[str, Finding] = {}
    for f in findings:
        uniq.setdefault(f.key, f)
    return list(uniq.values())


def _name_read_after(fn_body, name: str, after_lineno: int) -> bool:
    for node in ast.walk(fn_body):
        if isinstance(node, ast.Name) and node.id == name \
                and isinstance(node.ctx, ast.Load) \
                and getattr(node, "lineno", 0) >= after_lineno:
            return True
    return False


def _check_token_drops(rel: str, info: _ModuleInfo,
                       producers: dict | None = None) -> list:
    producers = TOKEN_PRODUCERS if producers is None else producers

    def produces(call: ast.Call) -> str | None:
        f = call.func
        name = f.attr if isinstance(f, ast.Attribute) else \
            (f.id if isinstance(f, ast.Name) else None)
        return name if name in producers else None

    findings = []
    for fn in info.functions.values():
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
                name = produces(stmt.value)
                if name:
                    findings.append(Finding(
                        "dropped-ordering-token", rel, fn.name,
                        f"{name}:discarded",
                        f"{name} result (value, a2a_token) discarded as a "
                        f"bare statement at {rel}:{stmt.lineno} — the "
                        f"ordering token must be threaded to "
                        f"ordered_after/the reduce schedule",
                        lineno=stmt.lineno))
            elif isinstance(stmt, ast.Assign) \
                    and isinstance(stmt.value, ast.Call) \
                    and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Tuple):
                name = produces(stmt.value)
                if not name:
                    continue
                tok_i = producers[name]
                elts = stmt.targets[0].elts
                if tok_i >= len(elts):
                    continue
                tgt = elts[tok_i]
                if not isinstance(tgt, ast.Name):
                    continue
                if tgt.id == "_" or not _name_read_after(
                        fn, tgt.id, stmt.lineno + 1):
                    findings.append(Finding(
                        "dropped-ordering-token", rel, fn.name,
                        f"{name}:{tgt.id}",
                        f"{name} ordering token bound to '{tgt.id}' at "
                        f"{rel}:{stmt.lineno} but never used — the "
                        f"backward a2a loses its ordering edge",
                        lineno=stmt.lineno))
    return findings


# ------------------------------------------------------------ entry point

def analyze_collectives(src_root: str, *, rel_prefix: str = "src/repro",
                        canon: set | None = None,
                        producers: dict | None = None) -> list:
    """Run pass 2 over every .py under ``src_root`` (skipping axes.py and
    this analysis package itself)."""
    canon = canonical_axes() if canon is None else canon
    findings = []
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        if os.path.basename(dirpath) == "analysis":
            continue
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root).replace(os.sep, "/")
            rel = f"{rel_prefix}/{rel}" if rel_prefix else rel
            if rel.endswith("core/axes.py"):
                continue
            with open(path) as fh:
                tree = ast.parse(fh.read(), filename=path)
            info = _ModuleInfo(tree)
            findings.extend(_check_axis_literals(rel, tree, canon))
            findings.extend(_check_collectives(rel, info, canon))
            findings.extend(_check_token_drops(rel, info, producers))
    return findings
