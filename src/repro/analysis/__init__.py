"""Static contract checker for the repro codebase (``python -m
repro.analysis``).

Three passes:

1. :mod:`repro.analysis.kernels` — Pallas kernel contracts: static VMEM
   footprints at the paper model shapes, MXU/lane tile alignment, un-tiled
   scaling blocks, grid coverage (AST inventory x call-site registry).
2. :mod:`repro.analysis.collectives` — mesh collective contracts: axis
   names bound to :mod:`repro.core.axes`, no axis string literals, no
   dropped a2a ordering tokens.
3. :mod:`repro.analysis.retrace` — runtime retracing detector used by the
   serving-engine warmup test and the autoscale benchmark.

CI runs passes 1-2 against the committed ``ANALYSIS_BASELINE.json``: known
ceilings stay visible without failing the build; new findings fail it.
"""
from repro.analysis.findings import (Finding, load_baseline, new_findings,
                                     report_dict, sort_findings,
                                     write_baseline)
from repro.analysis.collectives import analyze_collectives, canonical_axes
from repro.analysis.kernels import (REGISTRY, ShapeCase, analyze_kernels,
                                    annotate_bench_rows, bench_row_vmem,
                                    build_cases, iter_pallas_sites)
from repro.analysis.retrace import (RetraceError, RetraceReport, no_retrace,
                                    supported)

__all__ = [
    "Finding", "load_baseline", "new_findings", "report_dict",
    "sort_findings", "write_baseline",
    "analyze_collectives", "canonical_axes",
    "REGISTRY", "ShapeCase", "analyze_kernels", "annotate_bench_rows",
    "bench_row_vmem", "build_cases", "iter_pallas_sites",
    "RetraceError", "RetraceReport", "no_retrace", "supported",
    "run_all",
]


def run_all(repo_root: str = ".", *, budget: int | None = None,
            scales=(1, 4)) -> list:
    """Passes 1 + 2 over a repo checkout -> sorted findings."""
    import os

    from repro.kernels.tiling import VMEM_BUDGET_BYTES
    budget = VMEM_BUDGET_BYTES if budget is None else budget
    findings = analyze_kernels(
        os.path.join(repo_root, "src", "repro", "kernels"),
        budget=budget, scales=scales)
    findings += analyze_collectives(os.path.join(repo_root, "src", "repro"))
    return sort_findings(findings)
