"""CLI for the static contract checker.

    PYTHONPATH=src python -m repro.analysis \
        --baseline ANALYSIS_BASELINE.json --fail-on-new \
        --report analysis_report.json

Exit codes: 0 clean / only-baseline findings; 2 with ``--fail-on-new``
when findings outside the baseline exist OR when baseline entries are
stale (fingerprints no longer produced — fixed findings must be removed
from the baseline so it only shrinks deliberately).  ``--write-baseline``
accepts the current findings as the new baseline (review the diff before
committing it).
``--annotate-bench`` rewrites a BENCH_kernels.json with per-row static
VMEM estimates vs the budget.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import (load_baseline, new_findings, report_dict,
                            run_all, write_baseline)
from repro.analysis.kernels import annotate_bench_rows
from repro.kernels.tiling import VMEM_BUDGET_BYTES


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", default=".",
                    help="repo root containing src/repro")
    ap.add_argument("--baseline", default=None,
                    help="ANALYSIS_BASELINE.json with accepted fingerprints")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 2 when findings not in the baseline exist, "
                         "or when baseline entries have gone stale")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to --baseline and exit")
    ap.add_argument("--report", default=None,
                    help="write the full findings report (JSON) here")
    ap.add_argument("--vmem-budget", type=int, default=VMEM_BUDGET_BYTES,
                    help="per-core VMEM budget in bytes")
    ap.add_argument("--scales", default="1,4",
                    help="comma-separated paper-shape divisors")
    ap.add_argument("--annotate-bench", default=None,
                    help="BENCH_kernels.json to annotate with static VMEM "
                         "estimates (rewritten in place)")
    args = ap.parse_args(argv)

    scales = tuple(int(s) for s in args.scales.split(","))
    findings = run_all(args.root, budget=args.vmem_budget, scales=scales)

    if args.annotate_bench:
        with open(args.annotate_bench) as fh:
            rows = json.load(fh)
        annotate_bench_rows(rows, args.vmem_budget)
        with open(args.annotate_bench, "w") as fh:
            json.dump(rows, fh, indent=1)
            fh.write("\n")
        print(f"annotated {len(rows)} rows in {args.annotate_bench}")

    if args.report:
        with open(args.report, "w") as fh:
            json.dump(report_dict(findings, budget=args.vmem_budget), fh,
                      indent=2)
            fh.write("\n")

    if args.write_baseline:
        if not args.baseline:
            ap.error("--write-baseline requires --baseline")
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else set()
    fresh = new_findings(findings, baseline)
    known = len(findings) - len(fresh)
    stale = sorted(baseline - {f.fingerprint for f in findings})

    by_cat: dict[str, int] = {}
    for f in findings:
        by_cat[f.category] = by_cat.get(f.category, 0) + 1
    print(f"repro.analysis: {len(findings)} finding(s) "
          f"({known} baseline, {len(fresh)} new, {len(stale)} stale)  "
          f"{json.dumps(by_cat, sort_keys=True)}")
    for f in findings:
        mark = "NEW " if f.fingerprint in {x.fingerprint for x in fresh} \
            else "    "
        print(f"  {mark}[{f.severity:7s}] {f.fingerprint}")
        print(f"        {f.message}")
    for fp in stale:
        print(f"  STALE {fp}")
        print("        baseline entry no longer produced — the finding was "
              "fixed; remove it from the baseline")

    if args.fail_on_new and (fresh or stale):
        if fresh:
            print(f"FAIL: {len(fresh)} new finding(s) not in baseline",
                  file=sys.stderr)
        if stale:
            print(f"FAIL: {len(stale)} stale baseline entr"
                  f"{'y' if len(stale) == 1 else 'ies'} — shrink the "
                  f"baseline to match", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
