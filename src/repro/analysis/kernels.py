"""Pass 1 — Pallas kernel contracts.

Hybrid AST + call-site registry:

* The **AST walk** enumerates every ``pl.pallas_call`` expression under
  ``src/repro/kernels/`` (module, enclosing function, grid arity, literal
  in_spec count).  Any site without a registry entry is an
  ``unregistered-kernel`` error — the regression gate that forces future
  kernels to declare their contract here — and arity disagreements between
  the AST and the registry are ``site-mismatch`` errors (stale registry).

* The **registry** evaluates each site numerically at every paper model
  shape (``configs/paper_models.py``, at scales 1 and 4): concrete block
  shapes via the same ``tiling.block_and_pad`` the kernels call, dtypes,
  index-map structure and scratch.  From that the checks compute:

  - ``vmem-over-budget``: static per-grid-step footprint (resident blocks
    once, streamed blocks twice for the double-buffered pipeline, plus
    scratch) exceeding the per-core budget;
  - ``misaligned-block``: block dims that are neither 1, nor the full array
    extent, nor a multiple of the lane/sublane tile for their dtype;
  - ``untiled-block``: blocks covering the full extent of a dim that scales
    with tokens (T), dispatch rows (R = E*C) or a contraction (K) — the
    PR-4 VMEM ceilings surfaced here until the dispatch/combine/matmul
    kernels were re-tiled (this check now guards against regressions);
  - ``grid-uncovered``: affine index maps whose tile x grid-steps product
    does not cover the padded array extent (or const-indexed dims smaller
    than the array — regions the kernel would silently never visit).

Index-map components are ``("c",)`` const, ``("g", axis)`` affine in one
grid axis, or ``("x",)`` computed (e.g. flash attention's GQA head map) —
computed maps stream (double-buffer) but are exempt from coverage.
"""
from __future__ import annotations

import ast
import dataclasses
import os

from repro.analysis.findings import Finding
from repro.configs.base import MoEConfig
from repro.configs.paper_models import (BERT2GPT2, BERT_LARGE, GPT2_MOE,
                                        TRANSFORMER_XL)
from repro.core.gating import capacity
from repro.core.microop import resolve_chunk_count
from repro.kernels.dispatch import combine_vmem_bytes, dispatch_vmem_bytes
from repro.kernels.tiling import (LANE, SUBLANE, VMEM_BUDGET_BYTES,
                                  block_and_pad, block_bytes, pad_to,
                                  sublane_for)

PAPER_MODELS = (TRANSFORMER_XL, GPT2_MOE, BERT2GPT2, BERT_LARGE)

# chunk count for the re-entrant micro-op pipeline variants: the default
# MoEConfig.n_microops, resolved per shape exactly as the runtime does
# (core.microop.resolve_chunk_count picks the largest divisor of C)
PIPELINE_MICROOPS = MoEConfig().n_microops

# token count for the static shape cases: global tokens at scale 1 (the
# per-device a2a payload of the paper's 16-expert training runs), shrunk
# with the model at smaller scales but floored at two lane tiles
BASE_TOKENS = 4096


# ---------------------------------------------------------------- shapes --

@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """One numeric evaluation point: a paper model at a benchmark scale."""
    name: str
    T: int     # tokens entering the MoE layer
    D: int     # model width
    F: int     # expert FFN width
    E: int     # experts
    K: int     # top-k
    C: int     # per-expert capacity (core.gating.capacity)
    R: int     # dispatch rows = E * C
    H: int     # attention heads
    HD: int    # head dim


def build_cases(scales=(1, 4)) -> list[ShapeCase]:
    cases = []
    for cfg in PAPER_MODELS:
        for s in scales:
            d = max(128, cfg.d_model // s)
            f = max(128, (cfg.moe.d_ff or cfg.d_ff) // s)
            t = max(256, BASE_TOKENS // s)
            c = capacity(t, cfg.moe.n_experts, cfg.moe.top_k,
                         cfg.moe.capacity_factor)
            cases.append(ShapeCase(
                name=f"{cfg.name}/s{s}", T=t, D=d, F=f,
                E=cfg.moe.n_experts, K=cfg.moe.top_k, C=c,
                R=cfg.moe.n_experts * c, H=cfg.n_heads,
                HD=max(8, d // cfg.n_heads)))
    return cases


# ------------------------------------------------------------- AST sites --

@dataclasses.dataclass
class AstSite:
    module: str            # repo-relative posix path
    qualname: str          # innermost enclosing function
    lineno: int
    grid_len: int | None   # None when the grid kwarg is not a literal tuple
    n_in_specs: int | None  # None when in_specs is not a literal list


def _is_pallas_call(node: ast.Call) -> bool:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr == "pallas_call"
    return isinstance(fn, ast.Name) and fn.id == "pallas_call"


def _kwarg(node: ast.Call, name: str):
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


class _SiteVisitor(ast.NodeVisitor):
    def __init__(self, module: str):
        self.module = module
        self.stack: list[str] = []
        self.sites: list[AstSite] = []

    def visit_FunctionDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        if _is_pallas_call(node):
            grid = _kwarg(node, "grid")
            specs = _kwarg(node, "in_specs")
            self.sites.append(AstSite(
                module=self.module,
                qualname=self.stack[-1] if self.stack else "<module>",
                lineno=node.lineno,
                grid_len=len(grid.elts) if isinstance(grid, ast.Tuple)
                else None,
                n_in_specs=len(specs.elts)
                if isinstance(specs, (ast.List, ast.Tuple)) else None))
        self.generic_visit(node)


def iter_pallas_sites(kernels_dir: str, rel_prefix: str = "") -> list[AstSite]:
    sites = []
    for fname in sorted(os.listdir(kernels_dir)):
        if not fname.endswith(".py"):
            continue
        path = os.path.join(kernels_dir, fname)
        rel = os.path.join(rel_prefix, fname).replace(os.sep, "/") \
            if rel_prefix else fname
        with open(path) as fh:
            tree = ast.parse(fh.read(), filename=path)
        v = _SiteVisitor(rel)
        v.visit(tree)
        sites.extend(v.sites)
    return sites


# ------------------------------------------------------- numeric model ----

CONST = ("c",)
EXPR = ("x",)


def grid_dim(axis: int):
    return ("g", axis)


@dataclasses.dataclass
class Block:
    name: str
    shape: tuple
    dtype: str
    index: tuple               # per-dim CONST / grid_dim(i) / EXPR
    array_shape: tuple | None = None   # padded full extents
    roles: dict = dataclasses.field(default_factory=dict)  # dim -> T/R/K

    @property
    def resident(self) -> bool:
        return all(c == CONST for c in self.index)

    @property
    def nbytes(self) -> int:
        return block_bytes(self.shape, self.dtype)


@dataclasses.dataclass
class SiteEval:
    module: str                # basename, e.g. "dispatch.py"
    qualname: str
    case: str
    grid: tuple
    inputs: list
    outputs: list
    scratch: list = dataclasses.field(default_factory=list)  # (shape, dtype)
    variant: str = ""          # distinguishes multiple call shapes per site

    def blocks(self):
        return list(self.inputs) + list(self.outputs)

    def footprint(self) -> int:
        """Static per-grid-step VMEM bytes: resident blocks live once for
        the whole call, streamed blocks are double-buffered by the
        pipeline, scratch persists."""
        total = 0
        for b in self.blocks():
            total += b.nbytes if b.resident else 2 * b.nbytes
        for shape, dtype in self.scratch:
            total += block_bytes(shape, dtype)
        return total

    def block_key(self, b: Block) -> str:
        return f"{self.variant}:{b.name}" if self.variant else b.name


# ------------------------------------------------------------- registry ---

def _eval_topk_gating(c: ShapeCase):
    bt, t_pad = block_and_pad(c.T, 1024)
    return [SiteEval(
        "topk_gating.py", "topk_gating_fused", c.name, (t_pad // bt,),
        inputs=[
            Block("x", (bt, c.D), "float32", (grid_dim(0), CONST),
                  (t_pad, c.D)),
            Block("router", (c.D, c.E), "float32", (CONST, CONST),
                  (c.D, c.E)),
        ],
        outputs=[
            Block("idx", (bt, c.K), "int32", (grid_dim(0), CONST),
                  (t_pad, c.K)),
            Block("w", (bt, c.K), "float32", (grid_dim(0), CONST),
                  (t_pad, c.K)),
            Block("probs", (bt, c.E), "float32", (grid_dim(0), CONST),
                  (t_pad, c.E)),
        ])]


def _chunk_capacity(c: ShapeCase) -> int:
    """Per-chunk capacity of the micro-op pipeline at this shape: C split
    into ``PIPELINE_MICROOPS`` uniform chunks, resolved like the runtime."""
    return c.C // resolve_chunk_count(c.C, PIPELINE_MICROOPS)


def _dispatch_rows_eval(c: ShapeCase, rows: int, variant: str) -> SiteEval:
    br, r_pad = block_and_pad(rows, 1024)
    bx, t_pad = block_and_pad(c.T, 512)
    ev = SiteEval(
        "dispatch.py", "dispatch_rows", c.name,
        (r_pad // br, t_pad // bx),
        inputs=[
            Block("src_tok", (br, 1), "int32", (grid_dim(0), CONST),
                  (r_pad, 1)),
            Block("scale", (br, 1), "float32", (grid_dim(0), CONST),
                  (r_pad, 1)),
            Block("x", (bx, c.D), "float32", (grid_dim(1), CONST),
                  (t_pad, c.D)),
        ],
        outputs=[
            Block("out", (br, c.D), "float32", (grid_dim(0), CONST),
                  (r_pad, c.D)),
        ],
        variant=variant)
    assert ev.footprint() == dispatch_vmem_bytes(br, bx, c.D), \
        "analyzer estimate diverged from kernels.dispatch.dispatch_vmem_bytes"
    return ev


def _eval_dispatch_rows(c: ShapeCase):
    # full-buffer call plus the chunk-granular shape the re-entrant micro-op
    # pipeline dispatches per landed chunk (R/n rows of the slot buffer)
    return [_dispatch_rows_eval(c, c.R, ""),
            _dispatch_rows_eval(c, c.E * _chunk_capacity(c), "chunk")]


def _combine_rows_eval(c: ShapeCase, rows: int, variant: str) -> SiteEval:
    bt, t_pad = block_and_pad(c.T, 1024)
    brf, r_pad = block_and_pad(rows, 512)
    ev = SiteEval(
        "dispatch.py", "combine_rows", c.name,
        (t_pad // bt, r_pad // brf),
        inputs=[
            Block("rows", (bt, c.K), "int32", (grid_dim(0), CONST),
                  (t_pad, c.K)),
            Block("weights", (bt, c.K), "float32", (grid_dim(0), CONST),
                  (t_pad, c.K)),
            Block("buf", (brf, c.D), "float32", (grid_dim(1), CONST),
                  (r_pad, c.D)),
        ],
        outputs=[
            Block("out", (bt, c.D), "float32", (grid_dim(0), CONST),
                  (t_pad, c.D)),
        ],
        variant=variant)
    assert ev.footprint() == combine_vmem_bytes(bt, brf, c.D, c.K), \
        "analyzer estimate diverged from kernels.dispatch.combine_vmem_bytes"
    return ev


def _eval_combine_rows(c: ShapeCase):
    return [_combine_rows_eval(c, c.R, ""),
            _combine_rows_eval(c, c.E * _chunk_capacity(c), "chunk")]


# the weighted replica split keeps only metadata resident: the [E, R]
# integer-cumsum weight table and the replica->slot map.  R here is the
# replica-table width — bounded by the device count; 64 is a conservative
# upper bound for the paper's largest testbed.
ROUTE_REPLICA_W = 64


def _eval_weighted_route(c: ShapeCase):
    bt, t_pad = block_and_pad(c.T, 1024)
    rw = ROUTE_REPLICA_W
    return [SiteEval(
        "dispatch.py", "weighted_route", c.name, (t_pad // bt,),
        inputs=[
            Block("expert_idx", (bt, c.K), "int32", (grid_dim(0), CONST),
                  (t_pad, c.K)),
            Block("position", (bt, c.K), "int32", (grid_dim(0), CONST),
                  (t_pad, c.K)),
            Block("cum_weights", (c.E, rw), "int32", (CONST, CONST),
                  (c.E, rw)),
            Block("slot_of", (c.E, rw), "int32", (CONST, CONST),
                  (c.E, rw)),
        ],
        outputs=[
            Block("rows", (bt, c.K), "int32", (grid_dim(0), CONST),
                  (t_pad, c.K)),
        ])]


def _eval_topk_positions(c: ShapeCase):
    bt, t_pad = block_and_pad(c.T, 1024)
    e_pad = pad_to(max(c.E, 1), LANE)
    return [SiteEval(
        "topk_gating.py", "topk_positions", c.name, (c.K, t_pad // bt),
        inputs=[
            Block("idx", (bt, 1), "int32", (grid_dim(1), grid_dim(0)),
                  (t_pad, c.K)),
        ],
        outputs=[
            Block("pos", (bt, 1), "int32", (grid_dim(1), grid_dim(0)),
                  (t_pad, c.K)),
            Block("cnt", (SUBLANE, e_pad), "int32", (CONST, CONST),
                  (SUBLANE, e_pad)),
        ])]


def _grouped_ffn_eval(c: ShapeCase, cap: int, variant: str) -> SiteEval:
    bt, t_pad = block_and_pad(cap, 256)
    bf, f_pad = block_and_pad(c.F, 512, sub=LANE)
    g3 = (grid_dim(0), grid_dim(1), CONST)
    return SiteEval(
        "moe_ffn.py", "grouped_ffn", c.name,
        (c.E, t_pad // bt, f_pad // bf),
        inputs=[
            Block("x", (1, bt, c.D), "float32", g3, (c.E, t_pad, c.D)),
            Block("wi", (1, c.D, bf), "float32",
                  (grid_dim(0), CONST, grid_dim(2)), (c.E, c.D, f_pad)),
            Block("wu", (1, c.D, bf), "float32",
                  (grid_dim(0), CONST, grid_dim(2)), (c.E, c.D, f_pad)),
            Block("wo", (1, bf, c.D), "float32",
                  (grid_dim(0), grid_dim(2), CONST), (c.E, f_pad, c.D)),
        ],
        outputs=[
            Block("out", (1, bt, c.D), "float32", g3, (c.E, t_pad, c.D)),
        ],
        variant=variant)


def _eval_grouped_ffn(c: ShapeCase):
    # per-expert token extent is the dispatch capacity; the "chunk" variant
    # is the re-entrant call the micro-op pipeline issues per landed a2a
    # chunk (core.microop.pipelined_expert_ffn): same kernel, capacity C/n
    return [_grouped_ffn_eval(c, c.C, ""),
            _grouped_ffn_eval(c, _chunk_capacity(c), "chunk")]


# the grouped-FFN backward (kernels/ops.py::_grouped_ffn_bwd) expresses
# every dgrad/wgrad as a grouped_matmul; these are its gelu-path GEMM
# shapes.  The contraction dim is tiled (grid axis 3, innermost) with the
# output block revisited and accumulated — no full-K resident block.
_GMM_VARIANTS = (
    ("recompute_h", "C", "D", "F"),   # h  = x    @ wi
    ("dgrad_x", "C", "F", "D"),       # dx = dh   @ wi.T
    ("wgrad_in", "D", "C", "F"),      # dwi = x.T @ dh
    ("wgrad_out", "F", "C", "D"),     # dwo = act.T @ dy
)


def _eval_grouped_matmul(c: ShapeCase):
    evs = []
    dims = {"T": c.T, "C": c.C, "D": c.D, "F": c.F}
    for variant, m_r, k_r, n_r in _GMM_VARIANTS:
        m, k, n = dims[m_r], dims[k_r], dims[n_r]
        bm, m_pad = block_and_pad(m, 256)
        bn, n_pad = block_and_pad(n, 512, sub=LANE)
        bk, k_pad = block_and_pad(k, 512, sub=LANE)
        evs.append(SiteEval(
            "moe_ffn.py", "grouped_matmul", c.name,
            (c.E, m_pad // bm, n_pad // bn, k_pad // bk),
            inputs=[
                Block("a", (1, bm, bk), "float32",
                      (grid_dim(0), grid_dim(1), grid_dim(3)),
                      (c.E, m_pad, k_pad)),
                Block("b", (1, bk, bn), "float32",
                      (grid_dim(0), grid_dim(3), grid_dim(2)),
                      (c.E, k_pad, n_pad)),
            ],
            outputs=[
                Block("out", (1, bm, bn), "float32",
                      (grid_dim(0), grid_dim(1), grid_dim(2)),
                      (c.E, m_pad, n_pad)),
            ],
            variant=variant))
    return evs


def _eval_flash_attention(c: ShapeCase):
    b = 1
    s, hd = c.T, c.HD
    bq = bk = min(128, s)
    # GQA head map is computed, not affine: streamed, coverage-exempt
    kv_index = (EXPR, grid_dim(2), CONST)
    return [SiteEval(
        "flash_attention.py", "flash_attention", c.name,
        (b * c.H, s // bq, s // bk),
        inputs=[
            Block("q", (1, bq, hd), "float32",
                  (grid_dim(0), grid_dim(1), CONST), (b * c.H, s, hd)),
            Block("k", (1, bk, hd), "float32", kv_index, (b * c.H, s, hd)),
            Block("v", (1, bk, hd), "float32", kv_index, (b * c.H, s, hd)),
        ],
        outputs=[
            Block("out", (1, bq, hd), "float32",
                  (grid_dim(0), grid_dim(1), CONST), (b * c.H, s, hd)),
        ],
        scratch=[((bq, 1), "float32"), ((bq, 1), "float32"),
                 ((bq, hd), "float32")])]


def _eval_rwkv6(_c=None):
    # canonical rwkv6-1.6b time-mix shape: hd = 64, chunk = 64
    b, h, t, hd, chunk = 8, 32, 1024, 64, 64
    tile = (grid_dim(0), grid_dim(1), CONST)
    blk = [Block(n, (1, chunk, hd), "float32", tile, (b * h, t, hd))
           for n in ("r", "k", "v", "w")]
    return [SiteEval(
        "rwkv6.py", "rwkv6_wkv", "canonical", (b * h, t // chunk),
        inputs=blk + [Block("u", (1, hd), "float32",
                            (grid_dim(0), CONST), (b * h, hd))],
        outputs=[Block("out", (1, chunk, hd), "float32", tile,
                       (b * h, t, hd))],
        scratch=[((hd, hd), "float32")])]


def _eval_ssd(_c=None):
    # canonical zamba2 SSD shape: P = 64, N = 128, chunk Q = 128
    bsz, h, t, p, n, q = 8, 24, 1024, 64, 128, 128
    tile = (grid_dim(0), grid_dim(1), CONST)
    return [SiteEval(
        "ssd.py", "ssd_scan", "canonical", (bsz * h, t // q),
        inputs=[
            Block("x", (1, q, p), "float32", tile, (bsz * h, t, p)),
            Block("dt", (1, q), "float32", (grid_dim(0), grid_dim(1)),
                  (bsz * h, t)),
            Block("a_log", (1, 1), "float32", (grid_dim(0), CONST),
                  (bsz * h, 1)),
            Block("b", (1, q, n), "float32", tile, (bsz * h, t, n)),
            Block("c", (1, q, n), "float32", tile, (bsz * h, t, n)),
            Block("d_skip", (1, 1), "float32", (grid_dim(0), CONST),
                  (bsz * h, 1)),
        ],
        outputs=[Block("out", (1, q, p), "float32", tile, (bsz * h, t, p))],
        scratch=[((p, n), "float32")])]


@dataclasses.dataclass(frozen=True)
class RegistryEntry:
    eval_fn: object            # ShapeCase -> list[SiteEval]
    per_case: bool = True      # False: one canonical evaluation


REGISTRY = {
    ("topk_gating.py", "topk_gating_fused"):
        RegistryEntry(_eval_topk_gating),
    ("topk_gating.py", "topk_positions"):
        RegistryEntry(_eval_topk_positions),
    ("dispatch.py", "dispatch_rows"): RegistryEntry(_eval_dispatch_rows),
    ("dispatch.py", "combine_rows"): RegistryEntry(_eval_combine_rows),
    ("dispatch.py", "weighted_route"): RegistryEntry(_eval_weighted_route),
    ("moe_ffn.py", "grouped_ffn"): RegistryEntry(_eval_grouped_ffn),
    ("moe_ffn.py", "grouped_matmul"): RegistryEntry(_eval_grouped_matmul),
    ("flash_attention.py", "flash_attention"):
        RegistryEntry(_eval_flash_attention),
    ("rwkv6.py", "rwkv6_wkv"): RegistryEntry(_eval_rwkv6, per_case=False),
    ("ssd.py", "ssd_scan"): RegistryEntry(_eval_ssd, per_case=False),
}


# --------------------------------------------------------------- checks ---

def check_vmem(ev: SiteEval, budget: int, module: str) -> list:
    fp = ev.footprint()
    if fp <= budget:
        return []
    top = max(ev.blocks(), key=lambda b: b.nbytes)
    key = f"{ev.variant}@{ev.case}" if ev.variant else ev.case
    return [Finding(
        "vmem-over-budget", module, ev.qualname, key,
        f"{ev.qualname}{'/' + ev.variant if ev.variant else ''} at "
        f"{ev.case}: static VMEM footprint {fp:,} B > budget {budget:,} B "
        f"(largest block: {top.name} {list(top.shape)} {top.dtype}, "
        f"{top.nbytes:,} B{' resident' if top.resident else ''})",
        data={"footprint_bytes": fp, "budget_bytes": budget,
              "largest_block": top.name})]


def check_alignment(ev: SiteEval, module: str) -> list:
    out = []
    for b in ev.blocks():
        if len(b.shape) < 1:
            continue
        needs = [(len(b.shape) - 1, LANE)]
        if len(b.shape) >= 2:
            needs.append((len(b.shape) - 2, sublane_for(b.dtype)))
        for dim, need in needs:
            size = int(b.shape[dim])
            full = b.array_shape and int(b.array_shape[dim]) == size
            if size == 1 or full or size % need == 0:
                continue
            out.append(Finding(
                "misaligned-block", module, ev.qualname,
                f"{ev.block_key(b)}[dim{dim}]",
                f"{ev.qualname}: block {b.name} dim {dim} = {size} is not "
                f"a multiple of the {need}-wide hardware tile for "
                f"{b.dtype} (and not the full array extent) — the "
                f"MXU/VPU will run under-utilized or relayout"))
    return out


def check_untiled(ev: SiteEval, module: str) -> list:
    out = []
    for b in ev.blocks():
        for dim, role in sorted(b.roles.items()):
            if b.array_shape is None:
                continue
            if int(b.shape[dim]) != int(b.array_shape[dim]):
                continue
            out.append(Finding(
                "untiled-block", module, ev.qualname,
                f"{ev.block_key(b)}[{role}]",
                f"{ev.qualname}{'/' + ev.variant if ev.variant else ''}: "
                f"block {b.name} holds the full {role}-extent "
                f"({int(b.shape[dim])} at {ev.case}) in VMEM — footprint "
                f"scales with {role} instead of the tile (known re-tiling "
                f"target)",
                severity="warning",
                data={"dim": dim, "role": role,
                      "extent": int(b.shape[dim])}))
    return out


def check_coverage(ev: SiteEval, module: str) -> list:
    out = []
    for b in ev.blocks():
        if b.array_shape is None:
            continue
        for dim, comp in enumerate(b.index):
            size = int(b.shape[dim])
            extent = int(b.array_shape[dim])
            if comp == CONST:
                covered = size == extent
            elif comp == EXPR:
                continue
            else:
                steps = int(ev.grid[comp[1]])
                covered = size * steps == extent
            if not covered:
                out.append(Finding(
                    "grid-uncovered", module, ev.qualname,
                    f"{ev.block_key(b)}[dim{dim}]@{ev.case}",
                    f"{ev.qualname}: block {b.name} dim {dim} tile {size} "
                    f"x its grid steps does not cover the padded extent "
                    f"{extent} at {ev.case} — part of the array is never "
                    f"visited (or written) by the index map"))
    return out


# ------------------------------------------------------------ entry points

def _module_path(basename: str, sites: list) -> str:
    for s in sites:
        if os.path.basename(s.module) == basename:
            return s.module
    return basename


def analyze_kernels(kernels_dir: str, *, budget: int = VMEM_BUDGET_BYTES,
                    scales=(1, 4), registry: dict | None = None,
                    rel_prefix: str = "src/repro/kernels") -> list:
    """Run pass 1: AST inventory x registry numerics -> findings."""
    registry = REGISTRY if registry is None else registry
    sites = iter_pallas_sites(kernels_dir, rel_prefix=rel_prefix)
    findings: list[Finding] = []
    seen: set[str] = set()

    def add(fs):
        for f in fs:
            if f.fingerprint not in seen:
                seen.add(f.fingerprint)
                findings.append(f)

    site_keys = {(os.path.basename(s.module), s.qualname) for s in sites}
    for s in sites:
        if (os.path.basename(s.module), s.qualname) not in registry:
            add([Finding(
                "unregistered-kernel", s.module, s.qualname, s.qualname,
                f"pl.pallas_call in {s.qualname} ({s.module}:{s.lineno}) "
                f"has no entry in repro.analysis.kernels.REGISTRY — declare "
                f"its block shapes so the VMEM/tiling contract is checked",
                lineno=s.lineno)])
    for (basename, qual), entry in registry.items():
        module = _module_path(basename, sites)
        if (basename, qual) not in site_keys:
            add([Finding(
                "missing-kernel", module, qual, qual,
                f"registry entry ({basename}, {qual}) matches no "
                f"pl.pallas_call site — kernel renamed or removed; update "
                f"the registry", severity="warning")])
            continue
        ast_site = next(s for s in sites
                        if os.path.basename(s.module) == basename
                        and s.qualname == qual)
        cases = build_cases(scales) if entry.per_case else [None]
        for case in cases:
            for ev in entry.eval_fn(case):
                if ast_site.grid_len is not None \
                        and ast_site.grid_len != len(ev.grid):
                    add([Finding(
                        "site-mismatch", module, qual,
                        f"grid{'@' + ev.variant if ev.variant else ''}",
                        f"{qual}: registry grid arity {len(ev.grid)} != "
                        f"AST literal grid arity {ast_site.grid_len} — "
                        f"the registry is stale",
                        lineno=ast_site.lineno)])
                if ast_site.n_in_specs is not None \
                        and ast_site.n_in_specs != len(ev.inputs):
                    add([Finding(
                        "site-mismatch", module, qual,
                        f"in_specs{'@' + ev.variant if ev.variant else ''}",
                        f"{qual}: registry declares {len(ev.inputs)} input "
                        f"blocks but the AST in_specs list has "
                        f"{ast_site.n_in_specs} — the registry is stale",
                        lineno=ast_site.lineno)])
                add(check_vmem(ev, budget, module))
                add(check_alignment(ev, module))
                add(check_untiled(ev, module))
                add(check_coverage(ev, module))
    return findings


# ----------------------------------------------------- bench annotation ---

def _bench_case(**kw) -> ShapeCase:
    base = dict(name=kw.pop("name", "bench"), T=0, D=0, F=0, E=1, K=2,
                C=0, R=0, H=1, HD=8)
    base.update(kw)
    return ShapeCase(**base)


def bench_row_vmem(row: dict) -> int | None:
    """Static VMEM estimate (bytes, max over the kernels the bench row
    exercises) for one BENCH_kernels.json row; None for unknown benches."""
    shape = row.get("shape", {})
    kind = row.get("bench")
    evs: list[SiteEval] = []
    if kind == "gating":
        c = _bench_case(T=shape["T"], D=shape["D"], E=shape["E"],
                        K=shape.get("k", 2))
        evs += _eval_topk_gating(c)
    elif kind == "dispatch_combine":
        c = _bench_case(T=shape["T"], D=shape["D"], E=shape["E"],
                        C=shape["C"], R=shape["E"] * shape["C"],
                        K=shape.get("k", 2))
        evs += _eval_dispatch_rows(c) + _eval_combine_rows(c)
    elif kind == "routing":
        c = _bench_case(T=shape["T"], E=shape["E"], K=shape.get("k", 2))
        evs += _eval_topk_positions(c) + _eval_weighted_route(c)
    elif kind == "grouped_ffn":
        # the bench's T is already the per-expert row count
        c = _bench_case(E=shape["E"], C=shape["T"], D=shape["D"],
                        F=shape["F"])
        evs += _eval_grouped_ffn(c)
    elif kind == "layer_fwdbwd":
        t = shape["B"] * shape["S"]
        e, k = shape["E"], shape.get("k", 2)
        cap = capacity(t, e, k, 1.25)
        c = _bench_case(T=t, D=shape["D"], F=shape["F"], E=e, K=k,
                        C=cap, R=e * cap)
        evs += (_eval_topk_gating(c) + _eval_topk_positions(c)
                + _eval_dispatch_rows(c) + _eval_combine_rows(c)
                + _eval_grouped_ffn(c) + _eval_grouped_matmul(c))
    else:
        return None
    return max(ev.footprint() for ev in evs)


def annotate_bench_rows(rows: list, budget: int = VMEM_BUDGET_BYTES) -> list:
    """Attach static_vmem_bytes / vmem_budget_bytes / vmem_fits to each
    bench row (in place; returns rows)."""
    for row in rows:
        est = bench_row_vmem(row)
        if est is None:
            continue
        row["static_vmem_bytes"] = est
        row["vmem_budget_bytes"] = budget
        row["vmem_fits"] = est <= budget
    return rows
