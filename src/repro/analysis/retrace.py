"""Pass 3 — retracing detector (the only runtime pass).

Steady-state serving and training must not re-trace: a new trace means a
new shape/dtype/static-arg reached a jitted function, which on TPU stalls
the serving engine for seconds (the paper's motivation for shape-stable
scheduling).  ``no_retrace()`` wraps a steady-state window and asserts the
jit tracing cache took zero new misses inside it.

Counting uses ``jax._src.test_util.count_jit_tracing_cache_miss`` when
available (it patches ``pjit``'s jaxpr-creation cache); repeat calls with
known shapes hit the C++ fast path and never reach it, so a warmed-up
engine counts exactly zero.  On JAX versions without the hook the detector
degrades to a null counter that reports ``count=None`` and never fails —
gated features must check ``supported()``.
"""
from __future__ import annotations

import contextlib
import dataclasses


class RetraceError(AssertionError):
    pass


@dataclasses.dataclass
class RetraceReport:
    where: str
    allow: int = 0
    count: int | None = None     # None until the window closes / unsupported

    @property
    def ok(self) -> bool:
        return self.count is None or self.count <= self.allow


def _counter_cm():
    try:
        from jax._src import test_util as jtu
        return jtu.count_jit_tracing_cache_miss()
    except (ImportError, AttributeError):
        return None


def supported() -> bool:
    return _counter_cm() is not None


@contextlib.contextmanager
def no_retrace(where: str = "steady-state", *, allow: int = 0,
               strict: bool = True):
    """Context manager asserting zero new jit traces inside the window.

    Yields a RetraceReport; ``report.count`` is filled when the window
    closes.  ``strict=False`` records without raising (the benchmark
    mode); ``allow`` tolerates a known number of first-call traces.
    """
    report = RetraceReport(where=where, allow=allow)
    cm = _counter_cm()
    if cm is None:
        yield report
        return
    with cm as count:
        yield report
    report.count = int(count[0])
    if strict and not report.ok:
        raise RetraceError(
            f"{report.count} new jit trace(s) during {where} "
            f"(allowed {allow}) — a shape/dtype/static-arg is not "
            f"stable across steady-state steps")
