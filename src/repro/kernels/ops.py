"""Public jit'd kernel wrappers — the ONLY kernel entry point models use.

On TPU the Pallas kernels compile natively; this container is CPU-only, so
``interpret=True`` executes the kernel bodies in Python for correctness
validation (the tests sweep shapes/dtypes against ref.py).  ``use_pallas``
defaults to the backend: models call these ops and transparently get the
kernel on TPU and the jnp oracle on CPU; passing ``use_pallas=True`` on CPU
forces interpret-mode kernels (the parity-test / ``compute_backend="pallas"``
path).

The MoE ops are differentiable: ``grouped_ffn_op`` carries a
``jax.custom_vjp`` whose backward expresses every dgrad/wgrad as a
``grouped_matmul`` (same tiled kernel shapes as the forward), and the fused
gating / dispatch / combine ops carry linear-map VJPs so the jitted train
step runs end-to-end on the kernel path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.dispatch import (combine_rows, dispatch_rows,
                                    invert_slots, weighted_route)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_ffn import grouped_ffn, grouped_matmul
from repro.kernels.rwkv6 import rwkv6_wkv
from repro.kernels.ssd import ssd_scan
from repro.kernels.topk_gating import topk_gating_fused, topk_positions


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def resolve_backend(name: str | None) -> str:
    """``MoEConfig.compute_backend`` -> concrete backend.

    ``"auto"`` (the default) picks the Pallas kernels on TPU and the XLA
    einsum path elsewhere; explicit ``"pallas"`` off-TPU runs the kernels in
    interpret mode (parity tests, kernel benchmarks).
    """
    if name in (None, "", "auto"):
        return "pallas" if on_tpu() else "xla"
    if name not in ("xla", "pallas"):
        raise ValueError(f"unknown compute backend {name!r}")
    return name


def _int_zero_ct(a):
    """Cotangent for an integer-dtype primal input (jax wants float0)."""
    return np.zeros(a.shape, jax.dtypes.float0)


# ---------------------------------------------------------------------------
# grouped expert FFN (fwd kernel + grouped-GEMM backward)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _grouped_ffn_pallas(x, wi, wu, wo, ffn_type):
    return grouped_ffn(x, wi, wu, wo, ffn_type=ffn_type,
                       interpret=_interpret())


def _grouped_ffn_fwd(x, wi, wu, wo, ffn_type):
    return _grouped_ffn_pallas(x, wi, wu, wo, ffn_type), (x, wi, wu, wo)


def _grouped_ffn_bwd(ffn_type, res, dy):
    x, wi, wu, wo = res
    dy = dy.astype(jnp.float32)
    xt = x.swapaxes(1, 2)                                # [E, D, T]
    h = grouped_matmul(x, wi)                            # recompute [E, T, F]
    if ffn_type == "swiglu":
        u = grouped_matmul(x, wu)
        act, act_vjp = jax.vjp(lambda a, b: jax.nn.silu(a) * b, h, u)
    else:
        act, act_vjp = jax.vjp(jax.nn.gelu, h)
    da = grouped_matmul(dy, wo.swapaxes(1, 2))           # [E, T, F]
    dwo = grouped_matmul(act.swapaxes(1, 2), dy)         # [E, F, D]
    if ffn_type == "swiglu":
        dh, du = act_vjp(da)
        dx = grouped_matmul(dh, wi.swapaxes(1, 2)) \
            + grouped_matmul(du, wu.swapaxes(1, 2))
        dwu = grouped_matmul(xt, du).astype(wu.dtype)
    else:
        (dh,) = act_vjp(da)
        dx = grouped_matmul(dh, wi.swapaxes(1, 2))
        dwu = None
    dwi = grouped_matmul(xt, dh)
    return (dx.astype(x.dtype), dwi.astype(wi.dtype), dwu,
            dwo.astype(wo.dtype))


_grouped_ffn_pallas.defvjp(_grouped_ffn_fwd, _grouped_ffn_bwd)


def grouped_ffn_op(x, wi, wu, wo, ffn_type: str = "swiglu",
                   use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_grouped_ffn(x, wi, wu, wo, ffn_type)
    return _grouped_ffn_pallas(x, wi, wu, wo, ffn_type)


# ---------------------------------------------------------------------------
# fused router gating (router matmul + softmax + top-k in one kernel)
# ---------------------------------------------------------------------------

def _gating_oracle(x, router, k):
    return ref.ref_topk_gating(x @ router, k)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _topk_gating_pallas(x, router, k):
    # idx travels as f32 through the custom-VJP boundary: an integer output
    # of a custom_vjp carries a concrete float0 tangent that poisons any
    # downstream int arithmetic when scan/shard_map linearize (and
    # stop_gradient is a no-op on ints); the f32->i32 cast outside has a
    # symbolically-zero tangent, which is what we want
    idx, w, probs = topk_gating_fused(x, k, router=router,
                                      interpret=_interpret())
    return idx.astype(jnp.float32), w, probs


def _gating_fwd(x, router, k):
    return _topk_gating_pallas(x, router, k), (x, router)


def _gating_bwd(k, res, cts):
    # idx is integer-valued (its f32 carrier gets no real cotangent);
    # w/probs backprop through the oracle formulation — same math as the
    # XLA path, so grads match it
    x, router = res
    _, dw, dprobs = cts
    _, vjp = jax.vjp(lambda x_, r_: _gating_oracle(x_, r_, k)[1:], x, router)
    return vjp((dw, dprobs))


_topk_gating_pallas.defvjp(_gating_fwd, _gating_bwd)


def topk_gating_op(x, router, k: int, use_pallas: bool | None = None):
    """Fused gating network: logits = x @ router folded into the softmax +
    top-k kernel.  x: [T, D]; router: [D, E] ->
    (idx [T,k] i32, w [T,k] f32 renormalized, probs [T,E] f32)."""
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return _gating_oracle(x, router, k)
    idx, w, probs = _topk_gating_pallas(x, router, k)
    return idx.astype(jnp.int32), w, probs


# ---------------------------------------------------------------------------
# fused dispatch metadata (priority positions + weighted replica routing)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _positions_pallas(expert_idx, n_experts):
    # integer output -> f32 carrier across the custom-VJP boundary (same
    # float0 rationale as _topk_gating_pallas)
    return topk_positions(expert_idx, n_experts,
                          interpret=_interpret()).astype(jnp.float32)


def _positions_fwd(expert_idx, n_experts):
    return _positions_pallas(expert_idx, n_experts), (expert_idx,)


def _positions_bwd(n_experts, res, dpos):
    (expert_idx,) = res
    return (_int_zero_ct(expert_idx),)


_positions_pallas.defvjp(_positions_fwd, _positions_bwd)


def topk_positions_op(expert_idx, n_experts: int,
                      use_pallas: bool | None = None):
    """GShard priority positions: expert_idx [T, k] i32 -> [T, k] i32
    choice-major rank within each expert (the capacity cumsum that was a
    [T, k, E] one-hot in core.gating, fused on the kernel path)."""
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_topk_positions(expert_idx, n_experts)
    return _positions_pallas(expert_idx, n_experts).astype(jnp.int32)


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _weighted_route_pallas(expert_idx, position, cum_weights, slot_of,
                           slot_cap):
    return weighted_route(expert_idx, position, cum_weights, slot_of,
                          slot_cap, interpret=_interpret()
                          ).astype(jnp.float32)


def _weighted_route_fwd(expert_idx, position, cum_weights, slot_of,
                        slot_cap):
    return (_weighted_route_pallas(expert_idx, position, cum_weights,
                                   slot_of, slot_cap),
            (expert_idx, position, cum_weights, slot_of))


def _weighted_route_bwd(slot_cap, res, drows):
    return tuple(_int_zero_ct(a) for a in res)


_weighted_route_pallas.defvjp(_weighted_route_fwd, _weighted_route_bwd)


def weighted_route_op(expert_idx, position, cum_weights, slot_of,
                      slot_cap: int, use_pallas: bool | None = None):
    """Weighted replica-bin routing (Lina §5/§6.2 zero-migration split):
    (expert, priority position) -> flat destination row given the
    per-(expert, replica) integer weight cumsum and replica->slot table;
    -1 = dropped.  Integer-exact on both backends."""
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_weighted_route(expert_idx, position, cum_weights,
                                      slot_of, slot_cap)
    return _weighted_route_pallas(expert_idx, position, cum_weights,
                                  slot_of, slot_cap).astype(jnp.int32)


# ---------------------------------------------------------------------------
# fused dispatch / combine (capacity-buffer scatter + weighted gather)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _dispatch_pallas(x, src_tok, tok_rows):
    return dispatch_rows(x, src_tok, interpret=_interpret())


def _dispatch_fwd(x, src_tok, tok_rows):
    return _dispatch_pallas(x, src_tok, tok_rows), (src_tok, tok_rows)


def _dispatch_bwd(res, dbuf):
    # dispatch is a (masked) permutation of token rows: the cotangent of
    # token t is the sum of its slot rows — an unweighted combine gather
    src_tok, tok_rows = res
    ones = jnp.ones(tok_rows.shape, jnp.float32)
    dx = combine_rows(dbuf, tok_rows, ones, interpret=_interpret())
    return dx, _int_zero_ct(src_tok), _int_zero_ct(tok_rows)


_dispatch_pallas.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine_pallas(buf, rows, weights):
    return combine_rows(buf, rows, weights, interpret=_interpret())


def _combine_fwd(buf, rows, weights):
    return _combine_pallas(buf, rows, weights), (buf, rows, weights)


def _combine_bwd(res, dy):
    buf, rows, weights = res
    r = buf.shape[0]
    # d buf: scatter w[t,k] * dy[t] into each (token, choice)'s slot row —
    # the dispatch kernel again, with the gate weight as the per-row scale
    src_tok, src_k = invert_slots(rows, r)
    w_flat = weights.reshape(-1).astype(jnp.float32)
    t, k = rows.shape
    scale = jnp.where(src_tok >= 0,
                      w_flat[jnp.maximum(src_tok * k + src_k, 0)], 0.0)
    dbuf = dispatch_rows(dy.astype(buf.dtype), src_tok, scale,
                         interpret=_interpret())
    # d weights: row-wise dot of dy with the gathered slot rows
    vals = buf[jnp.maximum(rows, 0)].astype(jnp.float32)     # [T, k, d]
    dw = jnp.sum(vals * dy.astype(jnp.float32)[:, None, :], axis=-1)
    dw = jnp.where(rows >= 0, dw, 0.0).astype(weights.dtype)
    return dbuf, _int_zero_ct(rows), dw


_combine_pallas.defvjp(_combine_fwd, _combine_bwd)


def dispatch_combine_op(use_pallas: bool | None = None):
    """Returns the (dispatch, combine) callables with backend dispatch baked
    in — mirrors ``core.dispatch.get_backend`` so models never import kernel
    modules directly.

    dispatch(x [T,d], src_tok [R] i32, tok_rows [T,k] i32) -> [R, d]
        scatter-to-capacity-rows; ``src_tok`` is the metadata-sized inverse
        map from ``kernels.dispatch.invert_slots``; ``tok_rows`` (the
        forward map, -1 = dropped) feeds the linear-map backward.
    combine(buf [R,d], rows [T,k] i32, w [T,k]) -> [T, d]
        gate-weighted gather of each token's slot rows.
    """
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return (lambda x, src_tok, tok_rows: ref.ref_dispatch_rows(x, src_tok),
                ref.ref_combine_rows)
    return _dispatch_pallas, _combine_pallas


# ---------------------------------------------------------------------------
# the remaining (non-MoE) kernels
# ---------------------------------------------------------------------------

def flash_attention_op(q, k, v, causal: bool = True, window: int = 0,
                       use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_attention(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=_interpret())


def rwkv6_op(r, k, v, w, u, use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_rwkv6(r, k, v, w, u)
    return rwkv6_wkv(r, k, v, w, u, interpret=_interpret())


def ssd_op(x, dt, a_log, b, c, d_skip, use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_ssd(x, dt, a_log, b, c, d_skip)
    return ssd_scan(x, dt, a_log, b, c, d_skip, interpret=_interpret())
