"""Public jit'd kernel wrappers.

On TPU the Pallas kernels compile natively; this container is CPU-only, so
``interpret=True`` executes the kernel bodies in Python for correctness
validation (the tests sweep shapes/dtypes against ref.py).  ``use_pallas``
defaults to the backend: models call these ops and transparently get the
kernel on TPU and the jnp oracle on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_ffn import grouped_ffn
from repro.kernels.rwkv6 import rwkv6_wkv
from repro.kernels.ssd import ssd_scan


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def grouped_ffn_op(x, wi, wu, wo, ffn_type: str = "swiglu",
                   use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_grouped_ffn(x, wi, wu, wo, ffn_type)
    return grouped_ffn(x, wi, wu, wo, ffn_type=ffn_type,
                       interpret=_interpret())


def flash_attention_op(q, k, v, causal: bool = True, window: int = 0,
                       use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_attention(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=_interpret())


def rwkv6_op(r, k, v, w, u, use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_rwkv6(r, k, v, w, u)
    return rwkv6_wkv(r, k, v, w, u, interpret=_interpret())


def ssd_op(x, dt, a_log, b, c, d_skip, use_pallas: bool | None = None):
    use = on_tpu() if use_pallas is None else use_pallas
    if not use:
        return ref.ref_ssd(x, dt, a_log, b, c, d_skip)
    return ssd_scan(x, dt, a_log, b, c, d_skip, interpret=_interpret())
