"""Pallas TPU kernels for the perf-critical compute layers, with pure-jnp
oracles (ref.py) and backend-dispatching wrappers (ops.py):

  moe_ffn          grouped expert FFN GEMM (the MoE hot spot, paper Fig. 2)
  topk_gating      fused router softmax + top-k
  flash_attention  online-softmax attention (causal/SWA/bidirectional, GQA)
  rwkv6            chunked WKV recurrence (rwkv6-1.6b)
  ssd              Mamba2 chunk scan (zamba2-1.2b)

Kernels compile natively on TPU; this container validates them with
``interpret=True`` (kernel bodies executed on CPU) against ref.py.
"""
from repro.kernels.ops import (grouped_ffn_op, flash_attention_op, rwkv6_op,
                               ssd_op, on_tpu)
from repro.kernels.topk_gating import topk_gating_fused
