"""Pallas TPU kernels for the perf-critical compute layers, with pure-jnp
oracles (ref.py) and backend-dispatching wrappers (ops.py):

  moe_ffn          grouped expert FFN GEMM (the MoE hot spot, paper Fig. 2)
                   + grouped_matmul, the dgrad/wgrad primitive of its VJP
  topk_gating      fused router matmul + softmax + top-k
  dispatch         fused capacity-buffer scatter / gate-weighted combine
  flash_attention  online-softmax attention (causal/SWA/bidirectional, GQA)
  rwkv6            chunked WKV recurrence (rwkv6-1.6b)
  ssd              Mamba2 chunk scan (zamba2-1.2b)

Kernels compile natively on TPU; this container validates them with
``interpret=True`` (kernel bodies executed on CPU) against ref.py.
"""
from repro.kernels.ops import (dispatch_combine_op, flash_attention_op,
                               grouped_ffn_op, on_tpu, resolve_backend,
                               rwkv6_op, ssd_op, topk_gating_op)
from repro.kernels.topk_gating import topk_gating_fused
