"""Fused router kernel: softmax + top-k (k<=2) + renormalized gate weights
in one VMEM pass over token tiles (the gating network of paper §2.1 — it
sits on the critical path before every dispatch a2a, so fusing removes two
HBM round-trips of the [T, E] probability matrix).

Grid: (T/bt,).  Block: logits [bt, E] resident in VMEM; outputs are the
top-k ids/weights + full probs (the popularity estimator consumes probs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, idx_ref, w_ref, probs_ref, *, k: int):
    x = logits_ref[...].astype(jnp.float32)            # [bt, E]
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)
    probs_ref[...] = probs

    e = x.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    p = probs
    ws, ids = [], []
    for _ in range(k):
        top = jnp.max(p, axis=-1)
        arg = jnp.argmax(p, axis=-1).astype(jnp.int32)
        ws.append(top)
        ids.append(arg)
        p = jnp.where(iota == arg[:, None], -1.0, p)
    w = jnp.stack(ws, axis=-1)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    idx_ref[...] = jnp.stack(ids, axis=-1)
    w_ref[...] = w


def topk_gating_fused(logits, k: int = 2, *, block_t: int = 1024,
                      interpret: bool = True):
    """logits: [T, E] -> (idx [T,k] i32, w [T,k] f32, probs [T,E] f32)."""
    t, e = logits.shape
    bt = min(block_t, t)
    while t % bt:
        bt //= 2
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, e), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((t, k), jnp.int32),
            jax.ShapeDtypeStruct((t, k), jnp.float32),
            jax.ShapeDtypeStruct((t, e), jnp.float32),
        ),
        interpret=interpret,
    )(logits)
