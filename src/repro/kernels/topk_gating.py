"""Fused router kernel: (optional router matmul) + softmax + top-k (k<=2) +
renormalized gate weights in one VMEM pass over token tiles (the gating
network of paper §2.1 — it sits on the critical path before every dispatch
a2a, so fusing removes two HBM round-trips of the [T, E] probability matrix
and, with the router folded in, the [T, E] logits round-trip as well).

Grid: (T/bt,).  Block: logits (or x [bt, D] + resident router [D, E])
in VMEM; outputs are the top-k ids/weights + full probs (the popularity
estimator consumes probs).  Ragged T pads up to the tile; padded rows are
sliced off by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import block_and_pad, default_interpret


def _softmax_topk(logits, idx_ref, w_ref, probs_ref, k: int):
    x = logits.astype(jnp.float32)                     # [bt, E]
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)
    probs_ref[...] = probs

    iota = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    p = probs
    ws, ids = [], []
    for _ in range(k):
        top = jnp.max(p, axis=-1)
        arg = jnp.argmax(p, axis=-1).astype(jnp.int32)
        ws.append(top)
        ids.append(arg)
        p = jnp.where(iota == arg[:, None], -1.0, p)
    w = jnp.stack(ws, axis=-1)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    idx_ref[...] = jnp.stack(ids, axis=-1)
    w_ref[...] = w


def _kernel(logits_ref, idx_ref, w_ref, probs_ref, *, k: int):
    _softmax_topk(logits_ref[...], idx_ref, w_ref, probs_ref, k)


def _fused_kernel(x_ref, router_ref, idx_ref, w_ref, probs_ref, *, k: int):
    x = x_ref[...]                                     # [bt, D]
    logits = jnp.dot(x, router_ref[...],
                     preferred_element_type=jnp.float32)
    # round like the unfused XLA path (bf16 matmul emits bf16) so both
    # backends pick identical experts
    _softmax_topk(logits.astype(x.dtype), idx_ref, w_ref, probs_ref, k)


def topk_gating_fused(logits_or_x, k: int = 2, *, router=None,
                      block_t: int = 1024, interpret: bool | None = None):
    """Without ``router``: logits [T, E] -> (idx [T,k] i32, w [T,k] f32,
    probs [T,E] f32).  With ``router`` [D, E]: the first argument is the
    token block x [T, D] and the router matmul is folded into the kernel.
    """
    if interpret is None:
        interpret = default_interpret()
    t = logits_or_x.shape[0]
    e = router.shape[-1] if router is not None else logits_or_x.shape[-1]
    bt, t_pad = block_and_pad(t, block_t)
    x = logits_or_x
    if t_pad != t:
        x = jnp.pad(x, ((0, t_pad - t), (0, 0)))
    if router is None:
        kern = functools.partial(_kernel, k=k)
        in_specs = [pl.BlockSpec((bt, e), lambda i: (i, 0))]
        args = (x,)
    else:
        kern = functools.partial(_fused_kernel, k=k)
        d = logits_or_x.shape[-1]
        in_specs = [pl.BlockSpec((bt, d), lambda i: (i, 0)),
                    pl.BlockSpec((d, e), lambda i: (0, 0))]
        args = (x, router)
    idx, w, probs = pl.pallas_call(
        kern,
        grid=(t_pad // bt,),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, e), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((t_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((t_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((t_pad, e), jnp.float32),
        ),
        interpret=interpret,
    )(*args)
    return idx[:t], w[:t], probs[:t]
