"""Fused router kernel: (optional router matmul) + softmax + top-k (k<=2) +
renormalized gate weights in one VMEM pass over token tiles (the gating
network of paper §2.1 — it sits on the critical path before every dispatch
a2a, so fusing removes two HBM round-trips of the [T, E] probability matrix
and, with the router folded in, the [T, E] logits round-trip as well).

Grid: (T/bt,).  Block: logits (or x [bt, D] + resident router [D, E])
in VMEM; outputs are the top-k ids/weights + full probs (the popularity
estimator consumes probs).  Ragged T pads up to the tile; padded rows are
sliced off by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import (LANE, SUBLANE, block_and_pad,
                                  default_interpret, pad_to)


def _softmax_topk(logits, idx_ref, w_ref, probs_ref, k: int):
    x = logits.astype(jnp.float32)                     # [bt, E]
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)
    probs_ref[...] = probs

    iota = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    p = probs
    ws, ids = [], []
    for _ in range(k):
        top = jnp.max(p, axis=-1)
        arg = jnp.argmax(p, axis=-1).astype(jnp.int32)
        ws.append(top)
        ids.append(arg)
        p = jnp.where(iota == arg[:, None], -1.0, p)
    w = jnp.stack(ws, axis=-1)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    idx_ref[...] = jnp.stack(ids, axis=-1)
    w_ref[...] = w


def _kernel(logits_ref, idx_ref, w_ref, probs_ref, *, k: int):
    _softmax_topk(logits_ref[...], idx_ref, w_ref, probs_ref, k)


def _fused_kernel(x_ref, router_ref, idx_ref, w_ref, probs_ref, *, k: int):
    x = x_ref[...]                                     # [bt, D]
    logits = jnp.dot(x, router_ref[...],
                     preferred_element_type=jnp.float32)
    # round like the unfused XLA path (bf16 matmul emits bf16) so both
    # backends pick identical experts
    _softmax_topk(logits.astype(x.dtype), idx_ref, w_ref, probs_ref, k)


def topk_gating_fused(logits_or_x, k: int = 2, *, router=None,
                      block_t: int = 1024, interpret: bool | None = None):
    """Without ``router``: logits [T, E] -> (idx [T,k] i32, w [T,k] f32,
    probs [T,E] f32).  With ``router`` [D, E]: the first argument is the
    token block x [T, D] and the router matmul is folded into the kernel.
    """
    if interpret is None:
        interpret = default_interpret()
    t = logits_or_x.shape[0]
    e = router.shape[-1] if router is not None else logits_or_x.shape[-1]
    bt, t_pad = block_and_pad(t, block_t)
    x = logits_or_x
    if t_pad != t:
        x = jnp.pad(x, ((0, t_pad - t), (0, 0)))
    if router is None:
        kern = functools.partial(_kernel, k=k)
        in_specs = [pl.BlockSpec((bt, e), lambda i: (i, 0))]
        args = (x,)
    else:
        kern = functools.partial(_fused_kernel, k=k)
        d = logits_or_x.shape[-1]
        in_specs = [pl.BlockSpec((bt, d), lambda i: (i, 0)),
                    pl.BlockSpec((d, e), lambda i: (0, 0))]
        args = (x, router)
    idx, w, probs = pl.pallas_call(
        kern,
        grid=(t_pad // bt,),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, e), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((t_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((t_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((t_pad, e), jnp.float32),
        ),
        interpret=interpret,
    )(*args)
    return idx[:t], w[:t], probs[:t]


def _pos_kernel(idx_ref, pos_ref, cnt_ref, *, e_pad: int):
    # The per-expert counter lives in the revisited second output block
    # (CONST index map -> persistent across grid steps); padded token rows
    # carry expert id -1, so their one-hot is all-zero and they neither
    # take a rank nor advance the counter.
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when((j == 0) & (i == 0))
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    idx = idx_ref[...][:, 0]                            # [bt]
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (idx.shape[0], e_pad), 1)).astype(jnp.int32)
    base = cnt_ref[0, :]                                # [e_pad]
    rank = jnp.cumsum(onehot, axis=0) - onehot
    pos_ref[...] = jnp.sum((rank + base[None, :]) * onehot,
                           axis=1)[:, None]
    cnt_ref[0, :] = base + jnp.sum(onehot, axis=0)


def topk_positions(expert_idx, n_experts: int, *, block_t: int = 1024,
                   interpret: bool | None = None):
    """GShard priority positions, fused: expert_idx [T, k] int32 (-1 for
    masked rows) -> position [T, k] int32, the choice-major rank of each
    (token, choice) within its expert — choice 0 of every token outranks
    choice 1 of any token, exactly the one-hot cumsum in
    ``core.gating.gating_from_topk``, without ever materializing the
    [T, k, E] one-hot in HBM.

    Grid (k, T/bt): the choice axis is OUTERMOST so priority order matches
    the reference; a [1, E] counter block is revisited across all grid
    steps and carries each expert's running count.
    """
    if interpret is None:
        interpret = default_interpret()
    t, k = expert_idx.shape
    bt, t_pad = block_and_pad(t, block_t)
    e_pad = pad_to(max(int(n_experts), 1), LANE)
    if t_pad != t:
        expert_idx = jnp.pad(expert_idx, ((0, t_pad - t), (0, 0)),
                             constant_values=-1)
    pos, _ = pl.pallas_call(
        functools.partial(_pos_kernel, e_pad=e_pad),
        grid=(k, t_pad // bt),
        in_specs=[pl.BlockSpec((bt, 1), lambda j, i: (i, j))],
        out_specs=(
            pl.BlockSpec((bt, 1), lambda j, i: (i, j)),
            pl.BlockSpec((SUBLANE, e_pad), lambda j, i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((t_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((SUBLANE, e_pad), jnp.int32),
        ),
        interpret=interpret,
    )(expert_idx.astype(jnp.int32))
    return pos[:t]
