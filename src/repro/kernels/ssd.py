"""Mamba2 SSD chunk-scan Pallas kernel (zamba2's backbone hot loop).

Grid (B*H, T/Q) with the chunk index innermost; the [P, N] SSM state
persists in VMEM scratch.  The intra-chunk part is the matmul form
(L-masked C·B^T decay matrix against the chunk inputs — MXU work), the
cross-chunk part applies the carried state; both write one output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref, h_scr, *,
            q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)                  # [Q, P]
    dt = jax.nn.softplus(dt_ref[0].astype(jnp.float32))   # [Q]
    a = -jnp.exp(a_ref[0, 0].astype(jnp.float32))     # scalar
    bmat = b_ref[0].astype(jnp.float32)               # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)               # [Q, N]
    d = d_ref[0, 0].astype(jnp.float32)               # scalar

    la = dt * a                                       # [Q] log-decay/step
    lcum = jnp.cumsum(la)                             # [Q]
    xd = x * dt[:, None]

    # intra-chunk: M[t,s] = (c_t.b_s) exp(Lt - Ls) for s<=t
    rel = lcum[:, None] - lcum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(tri, jnp.exp(rel), 0.0)
    cb = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)
    y = jnp.dot(cb * m, xd, preferred_element_type=jnp.float32)

    # cross-chunk: y += exp(Lt) * (C_t . h_prev)
    h = h_scr[...]                                    # [P, N]
    y += jnp.exp(lcum)[:, None] * jnp.dot(cmat, h.T,
                                          preferred_element_type=jnp.float32)

    o_ref[0] = (y + x * d).astype(o_ref.dtype)

    # state update: h' = exp(L_Q) h + sum_s exp(L_Q - L_s) xd_s b_s^T
    dec_end = jnp.exp(lcum[-1] - lcum)                # [Q]
    s_chunk = jnp.dot((xd * dec_end[:, None]).T, bmat,
                      preferred_element_type=jnp.float32)   # [P, N]
    h_scr[...] = jnp.exp(lcum[-1]) * h + s_chunk


def ssd_scan(x, dt, a_log, b, c, d_skip, *, chunk: int = 128,
             interpret: bool = True):
    """x: [B,T,H,P]; dt: [B,T,H]; a_log,d_skip: [H]; b,c: [B,T,N]
    -> y [B,T,H,P] f32."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, t)
    while t % q:
        q //= 2

    xh = x.transpose(0, 2, 1, 3).reshape(bsz * h, t, p)
    dth = dt.transpose(0, 2, 1).reshape(bsz * h, t)
    bh = jnp.broadcast_to(b[:, None], (bsz, h, t, n)).reshape(bsz * h, t, n)
    ch = jnp.broadcast_to(c[:, None], (bsz, h, t, n)).reshape(bsz * h, t, n)
    ah = jnp.broadcast_to(a_log[None], (bsz, h)).reshape(bsz * h, 1)
    dh = jnp.broadcast_to(d_skip[None], (bsz, h)).reshape(bsz * h, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(bsz * h, t // q),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, q), lambda g, ci: (g, ci)),
            pl.BlockSpec((1, 1), lambda g, ci: (g, 0)),
            pl.BlockSpec((1, q, n), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, q, n), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, 1), lambda g, ci: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda g, ci: (g, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, t, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xh, dth, ah, bh, ch, dh)
    return out.reshape(bsz, h, t, p).transpose(0, 2, 1, 3)
