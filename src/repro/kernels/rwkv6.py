"""RWKV6 chunked-recurrence Pallas kernel (rwkv6-1.6b's time-mix hot loop).

Grid (B*H, T/chunk) with the chunk index innermost; the [hd, hd] wkv state
persists in VMEM scratch across chunks of one head.  Within a chunk the
recurrence runs as an unrolled loop of outer-product updates on VMEM tiles
(hd = 64: every operand is a single VREG-friendly [64, 64] tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                  # [hd]

    def step(t, s):
        r_t = r_ref[0, t].astype(jnp.float32)         # [hd]
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        w_t = w_ref[0, t].astype(jnp.float32)         # log-decay
        kv = k_t[:, None] * v_t[None, :]              # [hd, hd]
        y = jnp.sum((s + u[:, None] * kv) * r_t[:, None], axis=0)
        o_ref[0, t] = y.astype(o_ref.dtype)
        return jnp.exp(w_t)[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, step, s_scr[...])
    s_scr[...] = s


def rwkv6_wkv(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    """r/k/v/w: [B, T, H, hd] (w = log decay); u: [H, hd] -> y [B,T,H,hd]."""
    b, t, h, hd = r.shape
    c = min(chunk, t)
    while t % c:
        c //= 2
    # layout: [B*H, T, hd]
    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    rr, kk, vv, ww = map(to_bh, (r, k, v, w))
    uu = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=c),
        grid=(b * h, t // c),
        in_specs=[
            pl.BlockSpec((1, c, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, hd), lambda bh, ci: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, hd), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu)
    return out.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
