"""Fused dispatch/combine/route Pallas kernels: scatter tokens into
per-expert capacity buffers, gather them back gate-weighted, and map
(token, choice) pairs onto weighted replica rows — one pass each.

Neither side materializes the [T, E, C] one-hot dispatch mask (the einsum
oracle) nor the [T*k, d] broadcast copy of the token block (the jnp scatter
backend).  Instead the host-side caller inverts the metadata-sized
(token -> slot) map into a (slot -> token) int32 index (``invert_slots``,
one O(E*C) scatter of ids, no feature data), and:

  * ``dispatch_rows``  — grid over (output-row tile, source tile); each
    output tile is revisited across the streamed source tiles, gathering the
    rows that live in the current tile and accumulating (rows outside the
    tile contribute exactly 0.0, so the result is bitwise the single-pass
    gather).  An optional per-row scale also serves the combine-backward,
    where the scattered rows are gate-weighted cotangents.
  * ``combine_rows``   — grid over (token tile, buffer tile); each token
    tile is revisited across the streamed slot-buffer tiles and reduces its
    k gate-weighted slot rows in fp32.
  * ``weighted_route`` — grid over token tiles; the per-(expert, replica)
    integer routing weights (cumsum form) and the replica->slot table stay
    VMEM-resident while each tile turns (expert, position) into a flat
    destination row via bin partition — the Lina §5/§6.2 weighted
    zero-migration replica split, fused so dispatch metadata never leaves
    VMEM.

Since this PR no kernel here keeps a T- or R-scaling block resident: the
PR-4 ``untiled-block`` / scale-1 ``vmem-over-budget`` ceilings tracked in
``ANALYSIS_BASELINE.json`` are retired, and the call-time asserts below
enforce the new (all-streamed) footprints.

Empty slots / dropped choices are index -1 and come out exactly zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import (VMEM_BUDGET_BYTES, block_and_pad,
                                  default_interpret)


def dispatch_vmem_bytes(block_rows: int, block_src: int, d: int,
                        itemsize: int = 4) -> int:
    """Static per-grid-step VMEM footprint of ``dispatch_rows``.

    Everything streams double-buffered: the src/scale index columns and the
    fp32 [br, d] output tile per output step, plus the [bx, d] source tile
    per source step — no block scales with the full T extent any more (the
    PR-4 ``untiled-block`` ceiling, now retired)."""
    return 2 * (block_rows * 4 + block_rows * 4
                + block_src * d * itemsize + block_rows * d * 4)


def combine_vmem_bytes(block_t: int, block_r: int, d: int, k: int,
                       itemsize: int = 4) -> int:
    """Static per-grid-step VMEM footprint of ``combine_rows`` — the slot
    buffer streams in [brf, d] tiles (no R-resident block; PR-4 ceiling
    retired), rows/weights and the fp32 output tile double-buffer."""
    return 2 * (block_t * k * 4 + block_t * k * 4
                + block_r * d * itemsize + block_t * d * 4)


def _check_vmem(name: str, footprint: int, interpret: bool,
                vmem_budget: int | None, note: str) -> None:
    """Fail loudly (with the computed footprint) instead of a silent TPU
    OOM.  Interpret mode has no VMEM, so the check only fires natively —
    or whenever the caller pins an explicit ``vmem_budget``."""
    budget = vmem_budget
    if budget is None:
        budget = None if interpret else VMEM_BUDGET_BYTES
    if budget is not None and footprint > budget:
        raise ValueError(
            f"{name}: static VMEM footprint {footprint:,} B exceeds the "
            f"per-core budget {int(budget):,} B ({note} per "
            f"grid step — checked against repro.analysis pass 1; "
            f"shrink the block or split the call)")


def invert_slots(rows, n_rows: int):
    """[T, k] flat destination row per (token, choice), -1 for dropped ->
    ([n_rows] source token id, [n_rows] source choice id), -1 for empty.

    Metadata-sized (int32, no feature dim); gating guarantees destination
    rows are unique so a plain scatter-set is exact.
    """
    t, k = rows.shape
    flat = rows.reshape(-1)
    choice = jnp.arange(t * k, dtype=jnp.int32)
    tgt = jnp.where(flat < 0, n_rows, flat)
    src = jnp.full((n_rows + 1,), -1, jnp.int32)
    src = src.at[tgt].set(choice, mode="drop")[:-1]
    return jnp.where(src >= 0, src // k, -1), jnp.where(src >= 0, src % k, -1)


def _dispatch_kernel(src_ref, scale_ref, x_ref, o_ref, *, block_src: int):
    # source tiles stream along grid dim 1; the output tile is revisited,
    # zero-initialized on the first source tile and accumulated in fp32.
    # Each output row's source token lives in exactly one tile; the other
    # tiles add exactly 0.0, so the sum is bitwise the one-pass gather.
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = src_ref[...][:, 0]                            # [br] global token
    local = idx - j * block_src
    inside = (idx >= 0) & (local >= 0) & (local < block_src)
    rows = jnp.take(x_ref[...], jnp.clip(local, 0, block_src - 1), axis=0)
    s = jnp.where(inside, scale_ref[...][:, 0], 0.0)    # [br] f32
    o_ref[...] += rows.astype(jnp.float32) * s[:, None]


def dispatch_rows(x, src_tok, scale=None, *, block_rows: int = 1024,
                  block_src: int = 512, interpret: bool | None = None,
                  vmem_budget: int | None = None):
    """x: [T, d]; src_tok: [R] int32 source token per output row (-1 empty);
    scale: optional [R] f32 per-row weight (default 1).  -> [R, d] x.dtype.

    VMEM contract: the token block streams in [block_src, d] tiles (grid
    dim 1) — nothing scales with the full T extent, so all four paper
    shapes fit the per-core budget at scale=1.  Checked up front via
    ``dispatch_vmem_bytes`` (raises ValueError instead of a silent OOM).
    """
    if interpret is None:
        interpret = default_interpret()
    t, d = x.shape
    r = src_tok.shape[0]
    if scale is None:
        scale = jnp.ones((r,), jnp.float32)
    br, r_pad = block_and_pad(r, block_rows)
    bx, t_pad = block_and_pad(t, block_src)
    _check_vmem("dispatch_rows",
                dispatch_vmem_bytes(br, bx, d, x.dtype.itemsize),
                interpret, vmem_budget,
                f"streamed [bx={bx}, d={d}] source + [br={br}, d={d}] "
                f"output tiles")
    if r_pad != r:
        src_tok = jnp.pad(src_tok, (0, r_pad - r), constant_values=-1)
        scale = jnp.pad(scale, (0, r_pad - r))
    if t_pad != t:
        x = jnp.pad(x, ((0, t_pad - t), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dispatch_kernel, block_src=bx),
        grid=(r_pad // br, t_pad // bx),
        in_specs=[
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bx, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, d), jnp.float32),
        interpret=interpret,
    )(src_tok[:, None], scale.astype(jnp.float32)[:, None], x)
    return out[:r].astype(x.dtype)


def _combine_kernel(idx_ref, w_ref, buf_ref, o_ref, *, block_rows: int):
    # slot-buffer tiles stream along grid dim 1; each (token, choice) hits
    # exactly one tile (others add 0.0) and fp32 addition is commutative,
    # so the accumulated weighted sum equals the one-pass reduction bitwise.
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]                                  # [bt, k]
    local = idx - j * block_rows
    inside = (idx >= 0) & (local >= 0) & (local < block_rows)
    vals = jnp.take(buf_ref[...], jnp.clip(local, 0, block_rows - 1),
                    axis=0)                             # [bt, k, d]
    w = jnp.where(inside, w_ref[...], 0.0)              # [bt, k] f32
    o_ref[...] += jnp.sum(vals.astype(jnp.float32) * w[..., None], axis=1)


def combine_rows(buf, rows, weights, *, block_t: int = 1024,
                 block_rows: int = 512, interpret: bool | None = None,
                 vmem_budget: int | None = None):
    """buf: [R, d] slot rows; rows: [T, k] int32 flat slot per (token,
    choice), -1 dropped; weights: [T, k] gate weights.  -> [T, d] buf.dtype.

    VMEM contract: the slot buffer streams in [block_rows, d] tiles (grid
    dim 1) — no R-resident block — checked via ``combine_vmem_bytes``.
    """
    if interpret is None:
        interpret = default_interpret()
    r, d = buf.shape
    t, k = rows.shape
    bt, t_pad = block_and_pad(t, block_t)
    brf, r_pad = block_and_pad(r, block_rows)
    _check_vmem("combine_rows",
                combine_vmem_bytes(bt, brf, d, k, buf.dtype.itemsize),
                interpret, vmem_budget,
                f"streamed [brf={brf}, d={d}] buffer + [bt={bt}, d={d}] "
                f"output tiles")
    if t_pad != t:
        rows = jnp.pad(rows, ((0, t_pad - t), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, t_pad - t), (0, 0)))
    if r_pad != r:
        buf = jnp.pad(buf, ((0, r_pad - r), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_combine_kernel, block_rows=brf),
        grid=(t_pad // bt, r_pad // brf),
        in_specs=[
            pl.BlockSpec((bt, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, k), lambda i, j: (i, 0)),
            pl.BlockSpec((brf, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, d), jnp.float32),
        interpret=interpret,
    )(rows, weights.astype(jnp.float32), buf)
    return out[:t].astype(buf.dtype)


def _route_kernel(idx_ref, pos_ref, cum_ref, slot_ref, o_ref, *,
                  slot_cap: int):
    # bin partition: replica r owns positions [cum[r-1], cum[r]) of its
    # expert's GShard priority ranks.  Zero-weight (incl. dead/padded)
    # replicas never advance the cumsum, so they own an empty bin and are
    # skipped; pos >= total (= cum[-1]) is dropped.  Pure int32 arithmetic —
    # exactly equal to the XLA reference on both backends.
    idx_raw = idx_ref[...]                              # [bt, k]
    idx = jnp.maximum(idx_raw, 0)
    pos = pos_ref[...]                                  # [bt, k]
    cum = jnp.take(cum_ref[...], idx, axis=0)           # [bt, k, R]
    rw = cum.shape[-1]
    total = cum[..., -1]
    ge = pos[..., None] >= cum                          # [bt, k, R]
    which = jnp.minimum(jnp.sum(ge.astype(jnp.int32), axis=-1), rw - 1)
    prev = jnp.max(jnp.where(ge, cum, 0), axis=-1)      # cum[which-1] or 0
    slotvals = jnp.take(slot_ref[...], idx, axis=0)     # [bt, k, R]
    r_iota = jax.lax.broadcasted_iota(jnp.int32, cum.shape, 2)
    slot = jnp.sum(jnp.where(r_iota == which[..., None], slotvals, 0),
                   axis=-1)
    rows = slot * slot_cap + (pos - prev)
    keep = (idx_raw >= 0) & (pos < total) & (slot >= 0)
    o_ref[...] = jnp.where(keep, rows, -1)


def weighted_route(expert_idx, position, cum_weights, slot_of,
                   slot_cap: int, *, block_t: int = 1024,
                   interpret: bool | None = None):
    """Map each kept (token, choice) onto a weighted replica row.

    expert_idx: [T, k] int32 chosen expert (-1 allowed, treated dropped);
    position:   [T, k] int32 GShard priority rank within the expert;
    cum_weights:[E, R] int32 inclusive cumsum of the per-replica integer
                routing weights (constant past the live columns);
    slot_of:    [E, R] int32 global slot id per replica (-1 on pads);
    slot_cap:   rows per slot.  -> [T, k] int32 flat destination row
    (slot * slot_cap + within-replica offset), -1 for dropped.

    The [E, R] weight/slot tables are VMEM-resident (metadata-sized);
    token tiles stream.  Positions >= the expert's total integer weight
    are dropped — with weights from ``integer_route_weights`` that is
    exactly the capacity rule, with no per-slot recount afterwards.
    """
    if interpret is None:
        interpret = default_interpret()
    t, k = expert_idx.shape
    bt, t_pad = block_and_pad(t, block_t)
    if t_pad != t:
        expert_idx = jnp.pad(expert_idx, ((0, t_pad - t), (0, 0)),
                             constant_values=-1)
        position = jnp.pad(position, ((0, t_pad - t), (0, 0)))
    e, rw = cum_weights.shape
    out = pl.pallas_call(
        functools.partial(_route_kernel, slot_cap=int(slot_cap)),
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((e, rw), lambda i: (0, 0)),
            pl.BlockSpec((e, rw), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, k), jnp.int32),
        interpret=interpret,
    )(expert_idx.astype(jnp.int32), position.astype(jnp.int32),
      cum_weights.astype(jnp.int32), slot_of.astype(jnp.int32))
    return out[:t]
