"""Fused dispatch/combine Pallas kernels: scatter tokens into per-expert
capacity buffers and gather them back gate-weighted, in one pass each.

Neither side materializes the [T, E, C] one-hot dispatch mask (the einsum
oracle) nor the [T*k, d] broadcast copy of the token block (the jnp scatter
backend).  Instead the host-side caller inverts the metadata-sized
(token -> slot) map into a (slot -> token) int32 index (``invert_slots``,
one O(E*C) scatter of ids, no feature data), and:

  * ``dispatch_rows``  — grid over output-row tiles; each tile gathers its
    source rows straight out of the VMEM-resident token block and applies an
    optional per-row scale (scale also serves the combine-backward, where
    the scattered rows are gate-weighted cotangents).
  * ``combine_rows``   — grid over token tiles; each token gathers its k
    slot rows from the VMEM-resident buffer and reduces them with the gate
    weights in fp32.

Empty slots / dropped choices are index -1 and come out exactly zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import (VMEM_BUDGET_BYTES, block_and_pad,
                                  default_interpret)


def dispatch_vmem_bytes(t: int, d: int, block_rows: int,
                        itemsize: int = 4) -> int:
    """Static per-grid-step VMEM footprint of ``dispatch_rows``.

    The full [T, d] source block is RESIDENT (each output tile gathers from
    anywhere in it — the PR-4 ceiling tracked by ``repro.analysis`` as an
    ``untiled-block`` finding); the src/scale index columns and the [br, d]
    output tile stream through double-buffered.
    """
    resident = t * d * itemsize
    streamed = 2 * (block_rows * 4 + block_rows * 4
                    + block_rows * d * itemsize)
    return resident + streamed


def combine_vmem_bytes(r: int, d: int, block_t: int, k: int,
                       itemsize: int = 4) -> int:
    """Static per-grid-step VMEM footprint of ``combine_rows`` — the full
    [R, d] slot buffer is resident, token tiles stream double-buffered."""
    resident = r * d * itemsize
    streamed = 2 * (block_t * k * 4 + block_t * k * 4
                    + block_t * d * itemsize)
    return resident + streamed


def _check_vmem(name: str, footprint: int, interpret: bool,
                vmem_budget: int | None, note: str) -> None:
    """Fail loudly (with the computed footprint) instead of a silent TPU
    OOM.  Interpret mode has no VMEM, so the check only fires natively —
    or whenever the caller pins an explicit ``vmem_budget``."""
    budget = vmem_budget
    if budget is None:
        budget = None if interpret else VMEM_BUDGET_BYTES
    if budget is not None and footprint > budget:
        raise ValueError(
            f"{name}: static VMEM footprint {footprint:,} B exceeds the "
            f"per-core budget {int(budget):,} B ({note} is resident per "
            f"grid step — the re-tiling target tracked by repro.analysis; "
            f"shrink the block or split the call)")


def invert_slots(rows, n_rows: int):
    """[T, k] flat destination row per (token, choice), -1 for dropped ->
    ([n_rows] source token id, [n_rows] source choice id), -1 for empty.

    Metadata-sized (int32, no feature dim); gating guarantees destination
    rows are unique so a plain scatter-set is exact.
    """
    t, k = rows.shape
    flat = rows.reshape(-1)
    choice = jnp.arange(t * k, dtype=jnp.int32)
    tgt = jnp.where(flat < 0, n_rows, flat)
    src = jnp.full((n_rows + 1,), -1, jnp.int32)
    src = src.at[tgt].set(choice, mode="drop")[:-1]
    return jnp.where(src >= 0, src // k, -1), jnp.where(src >= 0, src % k, -1)


def _dispatch_kernel(src_ref, scale_ref, x_ref, o_ref):
    idx = src_ref[...][:, 0]                            # [br]
    rows = jnp.take(x_ref[...], jnp.maximum(idx, 0), axis=0)
    s = jnp.where(idx >= 0, scale_ref[...][:, 0], 0.0)  # [br] f32
    o_ref[...] = (rows.astype(jnp.float32) * s[:, None]).astype(o_ref.dtype)


def dispatch_rows(x, src_tok, scale=None, *, block_rows: int = 1024,
                  interpret: bool | None = None,
                  vmem_budget: int | None = None):
    """x: [T, d]; src_tok: [R] int32 source token per output row (-1 empty);
    scale: optional [R] f32 per-row weight (default 1).  -> [R, d] x.dtype.

    VMEM contract: the whole [T, d] token block is resident (the gather may
    touch any source row), so T*d*itemsize plus the double-buffered streamed
    tiles must fit the per-core budget — checked up front via
    ``dispatch_vmem_bytes`` (raises ValueError instead of a silent TPU OOM).
    """
    if interpret is None:
        interpret = default_interpret()
    t, d = x.shape
    r = src_tok.shape[0]
    if scale is None:
        scale = jnp.ones((r,), jnp.float32)
    br, r_pad = block_and_pad(r, block_rows)
    _check_vmem("dispatch_rows",
                dispatch_vmem_bytes(t, d, br, x.dtype.itemsize),
                interpret, vmem_budget, f"the un-tiled [T={t}, d={d}] block")
    if r_pad != r:
        src_tok = jnp.pad(src_tok, (0, r_pad - r), constant_values=-1)
        scale = jnp.pad(scale, (0, r_pad - r))
    out = pl.pallas_call(
        _dispatch_kernel,
        grid=(r_pad // br,),
        in_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((t, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, d), x.dtype),
        interpret=interpret,
    )(src_tok[:, None], scale.astype(jnp.float32)[:, None], x)
    return out[:r]


def _combine_kernel(idx_ref, w_ref, buf_ref, o_ref):
    idx = idx_ref[...]                                  # [bt, k]
    vals = jnp.take(buf_ref[...], jnp.maximum(idx, 0), axis=0)  # [bt, k, d]
    w = jnp.where(idx >= 0, w_ref[...], 0.0)            # [bt, k] f32
    o_ref[...] = jnp.sum(vals.astype(jnp.float32) * w[..., None],
                         axis=1).astype(o_ref.dtype)


def combine_rows(buf, rows, weights, *, block_t: int = 1024,
                 interpret: bool | None = None,
                 vmem_budget: int | None = None):
    """buf: [R, d] slot rows; rows: [T, k] int32 flat slot per (token,
    choice), -1 dropped; weights: [T, k] gate weights.  -> [T, d] buf.dtype.

    VMEM contract: the whole [R, d] slot buffer is resident (each token
    gathers arbitrary slots), checked up front via ``combine_vmem_bytes``.
    """
    if interpret is None:
        interpret = default_interpret()
    r, d = buf.shape
    t, k = rows.shape
    bt, t_pad = block_and_pad(t, block_t)
    _check_vmem("combine_rows",
                combine_vmem_bytes(r, d, bt, k, buf.dtype.itemsize),
                interpret, vmem_budget, f"the un-tiled [R={r}, d={d}] buffer")
    if t_pad != t:
        rows = jnp.pad(rows, ((0, t_pad - t), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, t_pad - t), (0, 0)))
    out = pl.pallas_call(
        _combine_kernel,
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((r, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, d), buf.dtype),
        interpret=interpret,
    )(rows, weights.astype(jnp.float32), buf)
    return out[:t]
