"""Flash attention Pallas kernel (causal / sliding-window / bidirectional).

Online-softmax over KV tiles: grid (B*H, Sq/bq, Skv/bk) with the KV index
innermost; running max m, denominator l and the fp32 accumulator persist in
VMEM scratch across the KV tiles of one (head, q-tile).  GQA is handled by
indexing the KV head as h // (H/KV) in the BlockSpec index maps, so no
jnp.repeat materialization.  Tiles are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, causal: bool, window: int, scale: float,
            n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                          # [bq, hd]
    k = k_ref[0]                                          # [bk, hd]
    v = v_ref[0]                                          # [bk, hd]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: [B, Sq, H, hd]; k/v: [B, Skv, KV, hd] -> [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    rep = h // kvh
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_k, skv)
    while skv % bk:
        bk //= 2
    n_k = skv // bk
    scale = hd ** -0.5

    # [B, S, H, hd] -> [B*H, S, hd] layout via transpose
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)

    def kv_index(bh, qi, ki):
        # GQA: flat query row bh = b*H + head -> kv row b*KV + head // rep
        return ((bh // h) * kvh + (bh % h) // rep, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale, n_k=n_k),
        grid=(b * h, sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
