"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the semantic ground truth: simple, obviously-correct
implementations with no tiling/fusion — tests sweep shapes/dtypes and assert
the kernels match these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_grouped_ffn(x, wi, wu, wo, ffn_type: str = "swiglu"):
    """Grouped expert FFN.  x: [E, T, D]; wi/wu: [E, D, F]; wo: [E, F, D]."""
    h = jnp.einsum("etd,edf->etf", x, wi)
    if ffn_type == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("etd,edf->etf", x, wu)
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("etf,efd->etd", h, wo).astype(x.dtype)


def ref_topk_gating(logits, k: int):
    """Fused router softmax + top-k.  logits: [T, E].
    Returns (expert_idx [T,k] i32, gate_w [T,k] f32 renormalized, probs)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return idx.astype(jnp.int32), w, probs


def ref_dispatch_rows(x, src_tok, scale=None):
    """Slot-buffer dispatch.  x: [T, d]; src_tok: [R] source token per slot
    row (-1 empty); scale: optional [R] f32.  -> [R, d] in x.dtype."""
    rows = x[jnp.maximum(src_tok, 0)]
    s = jnp.where(src_tok >= 0,
                  1.0 if scale is None else scale.astype(jnp.float32), 0.0)
    return (rows.astype(jnp.float32) * s[:, None]).astype(x.dtype)


def ref_combine_rows(buf, rows, weights):
    """Gate-weighted combine.  buf: [R, d]; rows: [T, k] flat slot per
    (token, choice), -1 dropped; weights: [T, k].  -> [T, d] in buf.dtype."""
    vals = buf[jnp.maximum(rows, 0)]                    # [T, k, d]
    w = jnp.where(rows >= 0, weights.astype(jnp.float32), 0.0)
    return jnp.sum(vals.astype(jnp.float32) * w[..., None],
                   axis=1).astype(buf.dtype)


def ref_topk_positions(expert_idx, n_experts: int):
    """GShard priority positions.  expert_idx: [T, k] int32 (-1 = masked)
    -> [T, k] int32 choice-major rank of each (token, choice) within its
    expert: all first choices outrank any second choice.  Masked rows get
    rank 0 and do not advance any counter."""
    t, k = expert_idx.shape
    onehot = (expert_idx[..., None]
              == jnp.arange(n_experts, dtype=jnp.int32)).astype(jnp.int32)
    flat = onehot.transpose(1, 0, 2).reshape(k * t, n_experts)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = pos.reshape(k, t, n_experts).transpose(1, 0, 2)
    return jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)


def ref_weighted_route(expert_idx, position, cum_weights, slot_of,
                       slot_cap: int, xp=jnp):
    """Weighted replica-bin routing (the ``weighted_route`` kernel oracle).

    expert_idx/position: [T, k] int32; cum_weights/slot_of: [E, R] int32
    (inclusive weight cumsum / global slot per replica, -1 pads);
    -> [T, k] int32 flat row (slot * slot_cap + offset), -1 dropped.

    Pure integer arithmetic, exactly the kernel's bin partition; pass
    ``xp=numpy`` for the host-side telemetry mirror.
    """
    idx = xp.maximum(expert_idx, 0)
    cum = xp.take(cum_weights, idx, axis=0)             # [T, k, R]
    rw = cum.shape[-1]
    total = cum[..., -1]
    ge = position[..., None] >= cum
    which = xp.minimum(xp.sum(ge.astype(xp.int32), axis=-1), rw - 1)
    prev = xp.max(xp.where(ge, cum, 0), axis=-1)
    slotvals = xp.take(slot_of, idx, axis=0)            # [T, k, R]
    r_iota = xp.arange(rw, dtype=xp.int32)
    slot = xp.sum(xp.where(r_iota[None, None, :] == which[..., None],
                           slotvals, 0), axis=-1)
    rows = slot * slot_cap + (position - prev)
    keep = (expert_idx >= 0) & (position < total) & (slot >= 0)
    return xp.where(keep, rows, -1).astype(xp.int32)


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, Sq, H, hd]; k/v: [B, Skv, KV, hd] -> [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / (hd ** 0.5)
    skv = k.shape[1]
    qpos, kpos = jnp.arange(sq), jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window:
        mask &= kpos[None] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def ref_rwkv6(r, k, v, w, u):
    """Naive RWKV6 recurrence.  r/k/v/w: [B, T, H, hd] (w = log decay < 0);
    u: [H, hd].  Returns y [B, T, H, hd] (f32)."""
    b, t, h, hd = r.shape

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                     # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]   # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = jnp.exp(w_t)[..., None] * s + kv
        return s, y

    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    seq = tuple(a.astype(jnp.float32).transpose(1, 0, 2, 3)
                for a in (r, k, v, w))
    _, ys = jax.lax.scan(step, s0, seq)
    return ys.transpose(1, 0, 2, 3)


def ref_ssd(x, dt, a_log, b, c, d_skip):
    """Naive Mamba2/SSD recurrence.  x: [B,T,H,P]; dt: [B,T,H] (pre-softplus);
    a_log: [H]; b,c: [B,T,N]; d_skip: [H].  Returns y [B,T,H,P] (f32)."""
    bsz, t, h, p = x.shape
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtp = jax.nn.softplus(dt.astype(jnp.float32))

    def step(s, inp):
        x_t, dt_t, b_t, c_t = inp                   # [B,H,P],[B,H],[B,N],[B,N]
        dec = jnp.exp(dt_t * a[None])               # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        s = s * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", s, c_t)
        return s, y

    s0 = jnp.zeros((bsz, h, p, b.shape[-1]), jnp.float32)
    seq = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
           dtp.transpose(1, 0, 2),
           b.astype(jnp.float32).transpose(1, 0, 2),
           c.astype(jnp.float32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, s0, seq)
    y = ys.transpose(1, 0, 2, 3)
    return y + x.astype(jnp.float32) * d_skip[None, None, :, None]
