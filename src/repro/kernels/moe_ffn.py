"""Grouped expert FFN Pallas kernel — the MoE compute hot spot (paper Fig. 2:
FFN follows the dispatch a2a; packing multiple experts per device makes this
a *grouped* GEMM, which XLA handles poorly as separate dots).

TPU mapping: grid (E, T/bt, F/bf).  Per step the MXU sees
[bt, D] @ [D, bf] -> act -> [bt, bf] @ [bf, D], accumulating the second
product over the F tiles into the fp32 output block (revisited across the
innermost grid dim).  Ragged T/F extents are padded up to the tile (zeros
flow through as zeros) instead of shrinking the tile below MXU alignment;
VMEM footprint = x(bt*D) + wi/wu/wo tiles (D*bf each) + out(bt*D) fp32.

``grouped_matmul`` is the same tiling discipline as a bare grouped GEMM —
the building block the custom-VJP backward (kernels/ops.py) uses to express
dgrad/wgrad, so fwd and bwd share MXU shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANE, block_and_pad, default_interpret


def _kernel(x_ref, wi_ref, wu_ref, wo_ref, o_ref, *, ffn_type: str):
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                   # [bt, D]
    h = jnp.dot(x, wi_ref[0], preferred_element_type=jnp.float32)
    if ffn_type == "swiglu":
        u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
        h = jax.nn.silu(h) * u
    else:
        h = jax.nn.gelu(h)
    o_ref[0] += jnp.dot(h.astype(x.dtype), wo_ref[0],
                        preferred_element_type=jnp.float32)


def grouped_ffn(x, wi, wu, wo, *, ffn_type: str = "swiglu",
                block_t: int = 256, block_f: int = 512,
                interpret: bool | None = None):
    """x: [E, T, D]; wi/wu: [E, D, F]; wo: [E, F, D] -> [E, T, D].

    ``wu`` may be None for gelu FFNs: the kernel never reads the up
    projection on that path, so ``wi`` is passed again as a zero-cost
    layout-compatible alias (no zeros tensor is materialized).
    """
    if interpret is None:
        interpret = default_interpret()
    e, t, d = x.shape
    f = wi.shape[-1]
    if wu is None:
        if ffn_type == "swiglu":
            raise ValueError("swiglu FFN requires the up projection wu")
        wu = wi
    bt, t_pad = block_and_pad(t, block_t)
    bf, f_pad = block_and_pad(f, block_f, sub=LANE)   # F is a lane dim in wi
    if t_pad != t:
        x = jnp.pad(x, ((0, 0), (0, t_pad - t), (0, 0)))
    if f_pad != f:
        # zero hidden units: h==0 there, gelu(0)=0 and silu(0)*0=0, and the
        # matching wo rows are zero — padded F contributes exactly nothing
        wi = jnp.pad(wi, ((0, 0), (0, 0), (0, f_pad - f)))
        wu = jnp.pad(wu, ((0, 0), (0, 0), (0, f_pad - f)))
        wo = jnp.pad(wo, ((0, 0), (0, f_pad - f), (0, 0)))
    grid = (e, t_pad // bt, f_pad // bf)
    out = pl.pallas_call(
        functools.partial(_kernel, ffn_type=ffn_type),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda e_, t_, f_: (e_, t_, 0)),
            pl.BlockSpec((1, d, bf), lambda e_, t_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e_, t_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e_, t_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda e_, t_, f_: (e_, t_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, t_pad, d), jnp.float32),
        interpret=interpret,
    )(x, wi, wu, wo)
    return out[:, :t].astype(x.dtype)


def _mm_kernel(a_ref, b_ref, o_ref):
    # K is the innermost grid dim: the output block is revisited across K
    # tiles, zero-initialized on the first visit and accumulated in fp32.
    # Padded K rows/cols are zeros, so they add exactly 0.0 — bitwise equal
    # to the single-pass product.
    @pl.when(pl.program_id(3) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0] += jnp.dot(a_ref[0], b_ref[0],
                        preferred_element_type=jnp.float32)


def grouped_matmul(a, b, *, block_m: int = 256, block_n: int = 512,
                   block_k: int = 512, interpret: bool | None = None):
    """Grouped GEMM: a [E, M, K] @ b [E, K, N] -> [E, M, N] in fp32.

    The dgrad/wgrad primitive of the grouped-FFN backward: every gradient
    of ``grouped_ffn`` is one of these per expert row, tiled exactly like
    the forward.  All three GEMM dims are blocked — K streams as the
    innermost grid axis accumulating into the revisited fp32 output block,
    so paper-width contractions (e.g. wgrad's K == T) no longer pin a
    full-K operand pair in VMEM.
    """
    if interpret is None:
        interpret = default_interpret()
    e, m, k = a.shape
    n = b.shape[-1]
    bm, m_pad = block_and_pad(m, block_m)
    bn, n_pad = block_and_pad(n, block_n, sub=LANE)   # N is the lane dim
    # K is a's lane dim AND b's sublane dim -> LANE-multiple tiles serve both
    bk, k_pad = block_and_pad(k, block_k, sub=LANE)
    if m_pad != m:
        a = jnp.pad(a, ((0, 0), (0, m_pad - m), (0, 0)))
    if k_pad != k:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, k_pad - k)))
        b = jnp.pad(b, ((0, 0), (0, k_pad - k), (0, 0)))
    if n_pad != n:
        b = jnp.pad(b, ((0, 0), (0, 0), (0, n_pad - n)))
    out = pl.pallas_call(
        _mm_kernel,
        grid=(e, m_pad // bm, n_pad // bn, k_pad // bk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e_, m_, n_, k_: (e_, m_, k_)),
            pl.BlockSpec((1, bk, bn), lambda e_, m_, n_, k_: (e_, k_, n_)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn),
                               lambda e_, m_, n_, k_: (e_, m_, n_)),
        out_shape=jax.ShapeDtypeStruct((e, m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:, :m, :n]
