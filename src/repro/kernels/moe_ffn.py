"""Grouped expert FFN Pallas kernel — the MoE compute hot spot (paper Fig. 2:
FFN follows the dispatch a2a; packing multiple experts per device makes this
a *grouped* GEMM, which XLA handles poorly as separate dots).

TPU mapping: grid (E, T/bt, F/bf).  Per step the MXU sees
[bt, D] @ [D, bf] -> act -> [bt, bf] @ [bf, D], accumulating the second
product over the F tiles into the fp32 output block (revisited across the
innermost grid dim).  All tile dims are multiples of 128 for MXU alignment;
VMEM footprint = x(bt*D) + wi/wu/wo tiles (D*bf each) + out(bt*D) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wi_ref, wu_ref, wo_ref, o_ref, *, ffn_type: str):
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                   # [bt, D]
    h = jnp.dot(x, wi_ref[0], preferred_element_type=jnp.float32)
    if ffn_type == "swiglu":
        u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
        h = jax.nn.silu(h) * u
    else:
        h = jax.nn.gelu(h)
    o_ref[0] += jnp.dot(h.astype(x.dtype), wo_ref[0],
                        preferred_element_type=jnp.float32)


def grouped_ffn(x, wi, wu, wo, *, ffn_type: str = "swiglu",
                block_t: int = 256, block_f: int = 512,
                interpret: bool = True):
    """x: [E, T, D]; wi/wu: [E, D, F]; wo: [E, F, D] -> [E, T, D]."""
    e, t, d = x.shape
    f = wi.shape[-1]
    bt = min(block_t, t)
    while t % bt:
        bt //= 2
    bf = min(block_f, f)
    while f % bf:
        bf //= 2
    if wu is None:
        wu = wo  # unused placeholder with a valid [E, ?, ?] layout
        assert ffn_type != "swiglu"
        wu = jnp.zeros_like(wi)
    grid = (e, t // bt, f // bf)
    out = pl.pallas_call(
        functools.partial(_kernel, ffn_type=ffn_type),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda e_, t_, f_: (e_, t_, 0)),
            pl.BlockSpec((1, d, bf), lambda e_, t_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e_, t_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e_, t_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda e_, t_, f_: (e_, t_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, t, d), jnp.float32),
        interpret=interpret,
    )(x, wi, wu, wo)
    return out.astype(x.dtype)
