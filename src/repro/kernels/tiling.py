"""Shared tiling policy for the Pallas kernels.

All kernels block their token/feature dims for the MXU; extents that do not
tile evenly are PADDED up to the chosen block rather than silently shrinking
the block below hardware alignment (a 1-wide tile turns the MXU into a
scalar unit).  Padding rows/columns are zeros, which every kernel here maps
to zeros (matmul, softmax-with-slice, masked gather), and the caller slices
the pad back off.
"""
from __future__ import annotations

import jax
import numpy as np

LANE = 128     # MXU/VPU lane width — ideal multiple for blocked dims
SUBLANE = 8    # f32 sublane height — minimum alignment for small extents

# ~16 MB of VMEM per TPU core (v4/v5 class) — the budget every kernel's
# static per-grid-step footprint is checked against (repro.analysis pass 1,
# and the call-time asserts in kernels/dispatch.py).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

# minimum sublane height by dtype width (pallas guide: f32 (8,128),
# bf16 (16,128), int8/fp8 (32,128))
_SUBLANE_BY_ITEMSIZE = {4: 8, 2: 16, 1: 32}


def sublane_for(dtype) -> int:
    """Minimum second-to-last-dim tile height for ``dtype``."""
    return _SUBLANE_BY_ITEMSIZE.get(np.dtype(dtype).itemsize, SUBLANE)


def block_bytes(shape, dtype) -> int:
    """Bytes of one VMEM block of ``shape`` x ``dtype``."""
    n = 1
    for s in shape:
        n *= int(s)
    return n * np.dtype(dtype).itemsize


def default_interpret() -> bool:
    """Pallas kernels compile natively on TPU; everywhere else the bodies
    run in interpret mode (the correctness-validation path in this
    CPU-only container)."""
    return jax.default_backend() != "tpu"


def pad_to(n: int, b: int) -> int:
    return -(-n // b) * b


def block_and_pad(n: int, block: int, align: int = LANE,
                  sub: int = SUBLANE) -> tuple[int, int]:
    """Choose a tile size for a dim of extent ``n`` under requested
    ``block``.  Returns ``(tile, padded_extent)``.

    * ``n`` divisible by a ``sub``-aligned ``min(block, n)`` -> keep the
      requested block and no padding (the fast path — production shapes
      are pre-aligned).
    * ``n <= align`` -> one ``sub``-aligned tile covering the whole
      (padded) extent.
    * otherwise -> the multiple of ``align`` (<= block, floored at
      ``align``) that minimizes the padded extent, ties to the larger
      tile.

    The tile is always a multiple of ``sub`` — ragged extents cost
    padding, never alignment.  Pass ``sub=LANE`` for a lane (last) block
    dim, where the hardware unit is 128 rather than the f32 sublane 8; an
    explicitly-requested unaligned ``block`` is bumped to the aligned
    choice rather than honored.
    """
    b = min(block, n)
    if b > 0 and n % b == 0 and b % sub == 0:
        return b, n
    if n <= align:
        b = pad_to(n, sub)
        return b, b
    best = align
    for cand in range(align, max(block, align) + 1, align):
        if pad_to(n, cand) <= pad_to(n, best):
            best = cand
    return best, pad_to(n, best)
