"""Deterministic fault schedules and the injector that replays them.

A ``FaultSchedule`` is a seeded, immutable list of ``Fault`` events keyed by
ENGINE STEP (not wall time — virtual-clock replay must reproduce bitwise).
``FaultInjector.attach`` hooks a schedule into a ``ServingEngine``:

  device_failure   the device stops computing: a fail-slow model multiplies
                   the step's modeled service time by ``magnitude`` scaled
                   by the token share the realized routing still lands on
                   it.  With resilience on, the failure is also REPORTED
                   (``scheduler.fail_devices`` / ``server.fail_devices``)
                   so the degradation ladder re-routes around it; naive
                   serving keeps routing into the failure and eats the
                   latency forever.
  straggler        same fail-slow model, but transient (``duration`` steps)
                   and never reported — the controller must see it through
                   telemetry, not an oracle.
  telemetry        the scheduler's view of the step's LayerStats is
                   corrupted (NaN popularity) while active; the bus's
                   validation (always-on) rejects the poisoned snapshots.
  planner_crash    the server's plan builds raise while active
                   (``MoEServer.fault_hook``); the watchdog ladder
                   (always-on) falls back instead of failing the batch.
  overload         ``n_requests`` synthetic requests are submitted in one
                   burst at the step's start; admission control (opt-in)
                   degrades the burst to explicit sheds/rejections.

Every random draw comes from ``np.random.RandomState(seed)`` — the same
seed replays the same faults against every engine variant, which is what
makes the chaos benchmark's degradation-on vs naive columns comparable.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as dc_replace
from typing import Dict, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("device_failure", "straggler", "telemetry", "planner_crash",
               "overload")


@dataclass(frozen=True)
class Fault:
    kind: str
    step: int                  # engine step the fault starts at (1-indexed:
    #                            engine.step_idx increments before firing)
    duration: int = 1          # steps the fault stays active; -1 = permanent
    device: int = -1           # device_failure / straggler target
    layer: int = -1            # telemetry target layer (-1 = all layers)
    magnitude: float = 4.0     # fail-slow service-time multiplier
    n_requests: int = 0        # overload burst size

    def active_at(self, step: int) -> bool:
        if step < self.step:
            return False
        return self.duration < 0 or step < self.step + self.duration


class FaultSchedule:
    """Immutable step-keyed fault list (sorted by start step)."""

    def __init__(self, faults):
        self.faults: Tuple[Fault, ...] = tuple(
            sorted(faults, key=lambda f: (f.step, f.kind, f.device)))

    def starting(self, step: int) -> List[Fault]:
        return [f for f in self.faults if f.step == step]

    def ending(self, step: int) -> List[Fault]:
        """Faults whose last active step was ``step - 1``."""
        return [f for f in self.faults
                if f.duration > 0 and f.step + f.duration == step]

    def active(self, step: int, kind: Optional[str] = None) -> List[Fault]:
        return [f for f in self.faults if f.active_at(step)
                and (kind is None or f.kind == kind)]

    def __len__(self) -> int:
        return len(self.faults)

    def __eq__(self, other) -> bool:
        return isinstance(other, FaultSchedule) and \
            self.faults == other.faults

    def __repr__(self) -> str:
        return f"FaultSchedule({list(self.faults)!r})"


def single_device_failure(step: int, device: int, duration: int = -1,
                          magnitude: float = 4.0) -> FaultSchedule:
    """The chaos suite's headline scenario: one device dies (permanently by
    default) partway through the trace."""
    return FaultSchedule([Fault("device_failure", step, duration=duration,
                                device=device, magnitude=magnitude)])


def overload_burst(step: int, n_requests: int) -> FaultSchedule:
    return FaultSchedule([Fault("overload", step, n_requests=n_requests)])


def chaos_schedule(seed: int, n_steps: int, n_devices: int,
                   n_layers: int = 1, kinds=FAULT_KINDS,
                   n_faults: int = 4, max_duration: int = 8,
                   magnitude: float = 4.0,
                   burst_requests: int = 8) -> FaultSchedule:
    """Seeded random schedule: ``n_faults`` events drawn uniformly over
    ``kinds`` and steps [2, n_steps].  Deterministic — the same arguments
    always produce an identical schedule (the determinism test pins this).
    At most one device_failure is emitted (and never on device 0) so a
    short chaos run cannot mask every device."""
    rng = np.random.RandomState(seed)
    faults: List[Fault] = []
    emitted_death = False
    for _ in range(n_faults):
        kind = kinds[rng.randint(len(kinds))]
        if kind == "device_failure" and (emitted_death or n_devices < 2):
            kind = "straggler"
        step = int(rng.randint(2, max(n_steps, 3)))
        dur = int(rng.randint(1, max_duration + 1))
        if kind == "device_failure":
            emitted_death = True
            faults.append(Fault(kind, step, duration=-1,
                                device=int(rng.randint(1, n_devices)),
                                magnitude=magnitude))
        elif kind == "straggler":
            faults.append(Fault(kind, step, duration=dur,
                                device=int(rng.randint(0, max(n_devices, 1))),
                                magnitude=magnitude))
        elif kind == "telemetry":
            faults.append(Fault(kind, step, duration=dur,
                                layer=int(rng.randint(-1, n_layers))))
        elif kind == "planner_crash":
            faults.append(Fault(kind, step, duration=dur))
        else:                                      # overload
            faults.append(Fault(kind, step,
                                n_requests=int(rng.randint(
                                    1, burst_requests + 1))))
    return FaultSchedule(faults)


class PlannerCrash(RuntimeError):
    """The injected planner exception (distinguishable from real bugs)."""


class FaultInjector:
    """Replays a ``FaultSchedule`` into an attached engine.

    ``resilience`` selects the degradation contrast the chaos benchmark
    measures: with it ON, detected device failures are reported to the
    scheduler/server (device-masked replanning + zero-migration re-route);
    OFF is the naive baseline — the same faults fire, but the planner stays
    blind to device health and keeps routing into the failure.  The
    always-on rungs (telemetry validation, controller isolation, planner
    watchdog) act in both modes, because they have no off switch in the
    stack either.
    """

    def __init__(self, schedule: FaultSchedule, resilience: bool = True,
                 rng_seed: int = 0, vocab_size: int = 256,
                 burst_seq_len: int = 8, burst_max_new_tokens: int = 0):
        self.schedule = schedule
        self.resilience = resilience
        self.rng = np.random.RandomState(rng_seed)
        self.vocab_size = int(vocab_size)
        self.burst_seq_len = int(burst_seq_len)
        self.burst_max_new_tokens = int(burst_max_new_tokens)
        self.engine = None
        self.scheduler = None
        self.server = None
        self.step = 0
        self.dead: set = set()            # devices currently failed
        self.events: Dict[str, int] = {}  # fired-fault ledger by kind
        self.injected = 0                 # overload requests submitted
        self.injected_rejected = 0        # ... of which the queue refused
        self.injected_rids: set = set()   # rids of accepted burst requests
        self.penalty_log: List[Tuple[int, float]] = []  # (step, fail-slow
        #                                  multiplier the step actually paid)
        self.fault_steps: Dict[str, List[int]] = {}

    # --- wiring -------------------------------------------------------------
    def attach(self, engine, scheduler=None) -> "FaultInjector":
        """Hook into ``engine`` (and its scheduler/server): step callback,
        service-model wrap, planner fault hook."""
        self.engine = engine
        engine.fault_injector = self
        self.scheduler = scheduler if scheduler is not None \
            else getattr(engine, "scheduler", None)
        self.server = engine.server
        engine.service_model = self._wrap_service_model(engine.service_model)
        self.server.fault_hook = self._plan_hook
        return self

    # --- the per-step driver ------------------------------------------------
    def on_step(self, engine, now: float) -> None:
        """Called by ``ServingEngine.step`` before batch formation."""
        self.step = engine.step_idx
        for f in self.schedule.ending(self.step):
            if f.kind == "device_failure" and f.device in self.dead:
                self.dead.discard(f.device)
                if self.resilience:
                    self._report_revive({f.device})
            # stragglers just lapse; telemetry/planner gates key on active()
        for f in self.schedule.starting(self.step):
            self.events[f.kind] = self.events.get(f.kind, 0) + 1
            self.fault_steps.setdefault(f.kind, []).append(self.step)
            # the fired-fault ledger, mirrored into the operator registry
            obs = getattr(engine, "obs", None)
            if obs is not None:
                obs.metrics.counter("faults_injected_total",
                                    kind=f.kind).inc()
            if f.kind == "device_failure":
                self.dead.add(f.device)
                if self.resilience:
                    self._report_failure({f.device})
            elif f.kind == "overload":
                self._inject_burst(engine, f, now)

    def _report_failure(self, devs) -> None:
        if self.scheduler is not None and hasattr(self.scheduler,
                                                  "fail_devices"):
            self.scheduler.fail_devices(devs)
        elif self.server is not None:
            self.server.fail_devices(devs)

    def _report_revive(self, devs) -> None:
        if self.scheduler is not None and hasattr(self.scheduler,
                                                  "revive_devices"):
            self.scheduler.revive_devices(devs)
        elif self.server is not None:
            self.server.revive_devices(devs)

    def _inject_burst(self, engine, f: Fault, now: float) -> None:
        for _ in range(f.n_requests):
            toks = self.rng.randint(0, self.vocab_size,
                                    size=(self.burst_seq_len,))
            rid = engine.submit(toks, arrival=now,
                                max_new_tokens=self.burst_max_new_tokens)
            self.injected += 1
            if rid >= 0:
                self.injected_rids.add(rid)
            if rid < 0:
                # burst traffic does not retry: the rejection is final, and
                # recorded so the accounting invariant still closes
                self.injected_rejected += 1
                engine.record_shed(-1, now, now, "rejected")

    # --- fault surfaces -----------------------------------------------------
    def _plan_hook(self, what: str, layer: int) -> None:
        if self.schedule.active(self.step, "planner_crash"):
            raise PlannerCrash(f"injected planner crash ({what}, layer "
                               f"{layer}, step {self.step})")

    def filter_stats(self, stats: List) -> List:
        """Telemetry corruption: while a telemetry fault is active the
        scheduler sees NaN popularity for the targeted layer(s).  The
        serving math is untouched — only the control loop's view."""
        active = self.schedule.active(self.step, "telemetry")
        if not active:
            return stats
        layers = {f.layer for f in active}
        out = []
        for s in stats:
            if -1 in layers or s.layer in layers:
                out.append(dc_replace(
                    s, actual_pop=np.full_like(
                        np.asarray(s.actual_pop, np.float64), np.nan)))
            else:
                out.append(s)
        return out

    def _slow_devices(self) -> Dict[int, float]:
        """Currently slow/dead devices -> service-time multiplier."""
        slow: Dict[int, float] = {}
        for f in self.schedule.active(self.step, "straggler"):
            slow[f.device] = max(slow.get(f.device, 1.0), f.magnitude)
        for f in self.schedule.faults:
            if f.kind == "device_failure" and f.device in self.dead:
                slow[f.device] = max(slow.get(f.device, 1.0), f.magnitude)
        return slow

    def _wrap_service_model(self, base):
        """Fail-slow service model: the step's modeled time inflates by the
        token share the realized routing still lands on dead/straggling
        devices (share * (magnitude - 1)).  Degradation that actually moves
        load off the device earns its recovery here — the modeled penalty
        follows the realized per-device ``device_load``, not an oracle flag.
        Every step's multiplier lands on ``penalty_log``: 1.0 means the
        step paid nothing for the fault — the exact same-step fault-free
        counterfactual the chaos benchmark's recovery clock needs."""
        n_dev = self.server.n_dev if self.server is not None else 1

        def wrapped(stats, n_tokens):
            t = float(base(stats, n_tokens)) if base is not None else 0.0
            slow = self._slow_devices()
            if not slow or not stats:
                self.penalty_log.append((self.step, 1.0))
                return t
            pen = 1.0
            for s in stats:
                per_dev = np.asarray(s.device_load, np.float64).reshape(-1)
                if per_dev.size != n_dev:
                    continue
                tot = per_dev.sum()
                if tot <= 0:
                    continue
                for d, mag in slow.items():
                    if 0 <= d < n_dev:
                        share = per_dev[d] / tot
                        pen = max(pen, 1.0 + share * (mag - 1.0))
            self.penalty_log.append((self.step, pen))
            return t * pen

        return wrapped

    # --- reporting ----------------------------------------------------------
    def report(self) -> dict:
        return {
            "resilience": self.resilience,
            "events": dict(self.events),
            "fault_steps": {k: list(v) for k, v in self.fault_steps.items()},
            "dead_devices": sorted(self.dead),
            "injected_requests": self.injected,
            "injected_rejected": self.injected_rejected,
        }
