"""Seeded fault injection + graceful-degradation glue (PR 9).

``faults`` builds deterministic fault schedules and drives them into the
serving stack (``ServingEngine`` / ``AdaptiveScheduler`` / ``MoEServer``);
the degradation paths themselves live where they act — device-masked
planning in ``core.placement`` / ``core.serving``, the phase-2 watchdog and
emergency replanning in ``runtime.server``, admission control in
``runtime.engine``, exception isolation in ``sched``, the non-finite guard
in ``runtime.trainer``, checksummed checkpoints in ``checkpoint.manager``.
"""
from repro.resilience.faults import (FAULT_KINDS, Fault, FaultInjector,
                                     FaultSchedule, chaos_schedule,
                                     overload_burst, single_device_failure)

__all__ = ["FAULT_KINDS", "Fault", "FaultInjector", "FaultSchedule",
           "chaos_schedule", "overload_burst", "single_device_failure"]
