"""Placement planner invariants (Eq. 1 + FFD + two-phase), with hypothesis."""
import numpy as np
from _hyp_compat import given, settings, st

from repro.core.placement import (identity_plan, needs_finetune,
                                  plan_placement, two_phase_plan)


@given(e=st.sampled_from([4, 8, 16]), seed=st.integers(0, 200),
       conc=st.sampled_from([0.2, 0.5, 1.0]))
@settings(max_examples=60, deadline=None)
def test_plan_invariants(e, seed, conc):
    rng = np.random.RandomState(seed)
    pop = rng.dirichlet(np.ones(e) * conc)
    n_dev = e
    plan = plan_placement(pop, n_dev, max_pack=4)
    # every expert is hosted at least once
    assert (plan.n_replicas >= 1).all()
    # replica slots are consistent with slot_expert
    for ex in range(e):
        for r in range(plan.n_replicas[ex]):
            slot = plan.replica_of[ex, r]
            d, s = divmod(int(slot), plan.max_pack)
            assert plan.slot_expert[d, s] == ex
    # no device hosts more than max_pack experts
    assert ((plan.slot_expert >= 0).sum(axis=1) <= plan.max_pack).all()


@given(e=st.sampled_from([8, 16]), seed=st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_plan_balances_skewed_load(e, seed):
    """Lina's plan must beat uniform placement on skewed popularity
    (paper Fig. 16-18: the whole point of §5)."""
    rng = np.random.RandomState(seed)
    pop = rng.dirichlet(np.ones(e) * 0.15)       # heavily skewed
    n_dev = e
    lina = plan_placement(pop, n_dev, max_pack=4)
    base = identity_plan(e, n_dev, max_pack=4)
    base = type(base)(base.slot_expert, base.replica_of, base.n_replicas,
                      pop.astype(np.float32))
    assert lina.device_load().max() <= base.device_load().max() + 1e-9


def test_two_phase_finetune_trigger():
    e = 8
    est = np.array([.4, .3, .1, .05, .05, .04, .03, .03])
    same = est + 1e-3
    assert not needs_finetune(est, same, top_k=1)
    flipped = est[::-1].copy()
    assert needs_finetune(est, flipped, top_k=1)
    _, ft = two_phase_plan(est, flipped, e, top_k=1)
    assert ft
    _, ft = two_phase_plan(est, same, e, top_k=1)
    assert not ft


def test_identity_plan_layout():
    plan = identity_plan(8, 4, max_pack=2)
    assert (plan.slot_expert == np.array([[0, 1], [2, 3], [4, 5], [6, 7]])).all()
    assert (plan.n_replicas == 1).all()


def test_replication_of_hot_expert():
    pop = np.array([0.7] + [0.3 / 7] * 7)
    plan = plan_placement(pop, 8, max_pack=4)
    assert plan.n_replicas[0] >= 2         # hot expert replicated
    assert plan.device_load().max() < 0.7  # and its load split
