"""Parity suites for the Pallas MoE hot-path backends.

``compute_backend="pallas"`` (fused gating + grouped FFN + fused
dispatch/combine, all in interpret mode on CPU) must be indistinguishable —
gating metadata exactly, numerics within dtype tolerance — from the XLA
einsum path, through the raw ops, the MoE layer, the jitted train step on a
multi-device mesh, and ``serve_moe_layer``.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.core import dispatch as D
from repro.core import init_moe_params, moe_layer
from repro.core.gating import capacity, router_top_k_gating, top_k_gating
from repro.core.placement import plan_placement
from repro.core.serving import PlanArrays, serve_moe_layer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _assert_gating_equal(a, b):
    assert (np.asarray(a.expert_idx) == np.asarray(b.expert_idx)).all()
    assert (np.asarray(a.position) == np.asarray(b.position)).all()
    assert (np.asarray(a.dropped) == np.asarray(b.dropped)).all()
    np.testing.assert_allclose(a.gate_weights, b.gate_weights, atol=1e-6)
    np.testing.assert_allclose(a.router_probs, b.router_probs, atol=1e-6)
    np.testing.assert_allclose(float(a.aux_loss), float(b.aux_loss),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# fused gating vs core.gating.top_k_gating
# ---------------------------------------------------------------------------

@given(t=st.sampled_from([16, 50, 128]), e=st.sampled_from([4, 8, 16]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_fused_gating_matches_topk_gating(t, e, k, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(keys[0], (t, 16))
    router = jax.random.normal(keys[1], (16, e)) * 0.3
    cap = capacity(t, e, k, 1.25)
    ref = top_k_gating(x @ router, k, cap)
    got = router_top_k_gating(x, router, k, cap, compute_backend="pallas")
    _assert_gating_equal(got, ref)


def test_fused_gating_tie_breaking():
    """Duplicated router columns produce exactly tied logits for every
    token; both backends must break the tie the same way (lowest index)."""
    t, d, e = 32, 8, 6
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    router = jax.random.normal(jax.random.PRNGKey(1), (d, e))
    router = router.at[:, 3].set(router[:, 1])      # cols 1 and 3 tie
    router = router.at[:, 5].set(router[:, 1])      # three-way tie
    cap = capacity(t, e, 2, 2.0)
    ref = top_k_gating(x @ router, 2, cap)
    got = router_top_k_gating(x, router, 2, cap, compute_backend="pallas")
    _assert_gating_equal(got, ref)
    # ties actually occur and resolve to the lowest expert index
    probs = np.asarray(ref.router_probs)
    assert (probs[:, 1] == probs[:, 3]).all()
    idx = np.asarray(ref.expert_idx)
    assert (idx != 5).all()                  # 3rd tie member never in top-2
    assert ((idx[:, 1] != 3) | (idx[:, 0] == 1)).all()  # 3 only after 1


def test_fused_gating_all_dropped():
    """Everyone wants expert 0 at tiny capacity: most tokens drop all their
    choices; drops/positions/zeroed weights must match exactly."""
    t, d, e = 256, 8, 4
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (t, d))) + 0.1
    router = jnp.zeros((d, e)).at[:, 0].set(10.0)
    cap = 8
    ref = top_k_gating(x @ router, 1, cap)
    got = router_top_k_gating(x, router, 1, cap, compute_backend="pallas")
    _assert_gating_equal(got, ref)
    dropped = np.asarray(ref.dropped)
    assert dropped.sum() == t - cap                 # all-but-cap dropped
    assert (np.asarray(got.gate_weights)[dropped] == 0).all()


def test_fused_gating_gradients_match():
    t, d, e = 48, 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    router = jax.random.normal(jax.random.PRNGKey(1), (d, e)) * 0.3
    cap = capacity(t, e, 2, 1.25)

    def loss(backend):
        def f(x, r):
            g = router_top_k_gating(x, r, 2, cap, compute_backend=backend)
            return (g.gate_weights ** 2).sum() + g.aux_loss
        return f

    gx = jax.jit(jax.grad(loss("xla"), argnums=(0, 1)))(x, router)
    gp = jax.jit(jax.grad(loss("pallas"), argnums=(0, 1)))(x, router)
    for a, b in zip(gx, gp):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# pallas dispatch backend vs einsum oracle
# ---------------------------------------------------------------------------

@given(t=st.sampled_from([16, 64]), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_pallas_dispatch_matches_einsum_oracle(t, e, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, 16))
    logits = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, e))
    cap = capacity(t, e, k, 2.0)
    g = top_k_gating(logits, k, cap)
    b1 = D.dispatch_einsum(x, g, e, cap)
    b2 = D.dispatch_pallas(x, g, e, cap)
    np.testing.assert_allclose(b1, b2, atol=1e-5)
    buf = jax.random.normal(jax.random.PRNGKey(seed + 2), (e, cap, 16))
    y1 = D.combine_einsum(buf, g, e, cap)
    y2 = D.combine_pallas(buf, g, e, cap)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)


def test_pallas_dispatch_gradients_match_oracle():
    t, e, k, d = 32, 4, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    logits = jax.random.normal(jax.random.PRNGKey(1), (t, e))
    cap = capacity(t, e, k, 2.0)
    g = top_k_gating(logits, k, cap)

    def roundtrip(backend):
        disp, comb = D.get_backend(backend)

        def f(x, w):
            gg = g._replace(gate_weights=w)
            buf = disp(x, gg, e, cap)
            return (comb(buf, gg, e, cap) ** 2).sum()
        return f

    gx = jax.jit(jax.grad(roundtrip("einsum"), argnums=(0, 1)))(
        x, g.gate_weights)
    gp = jax.jit(jax.grad(roundtrip("pallas"), argnums=(0, 1)))(
        x, g.gate_weights)
    np.testing.assert_allclose(gx[0], gp[0], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(gx[1], gp[1], atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# full layer / train step / serving
# ---------------------------------------------------------------------------

def _cfgs():
    cfg_x = MoEConfig(n_experts=4, top_k=2, d_ff=32, n_microops=2,
                      compute_backend="xla")
    return cfg_x, dataclasses.replace(cfg_x, compute_backend="pallas")


def test_moe_layer_pallas_backend_fwd_bwd():
    cfg_x, cfg_p = _cfgs()
    params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))

    a = jax.jit(lambda x, p: moe_layer(None, x, p, cfg_x))(x, params)
    b = jax.jit(lambda x, p: moe_layer(None, x, p, cfg_p,
                                       dispatch_backend="pallas"))(x, params)
    np.testing.assert_allclose(a.y, b.y, atol=1e-5)
    assert (np.asarray(a.expert_idx) == np.asarray(b.expert_idx)).all()
    np.testing.assert_allclose(float(a.aux_loss), float(b.aux_loss),
                               atol=1e-6)

    def loss(cfg, db):
        def f(x, p):
            out = moe_layer(None, x, p, cfg, dispatch_backend=db)
            return (out.y ** 2).sum() + out.aux_loss
        return f

    ga = jax.jit(jax.grad(loss(cfg_x, "scatter"), argnums=(0, 1)))(x, params)
    gb = jax.jit(jax.grad(loss(cfg_p, "pallas"), argnums=(0, 1)))(x, params)
    for u, v in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(u, v, atol=2e-4, rtol=1e-3)


def test_serve_moe_layer_pallas_backend_matches_xla():
    cfg_x, cfg_p = _cfgs()
    params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    for seed in range(3):
        pop = np.random.RandomState(seed).dirichlet(np.ones(4) * 0.3)
        plan = PlanArrays.from_plan(plan_placement(pop, 1, max_pack=4))
        y1, e1, p1 = jax.jit(lambda x, p, pl: serve_moe_layer(
            None, x, p, cfg_x, pl, top_k=1))(x, params, plan)
        y2, e2, p2 = jax.jit(lambda x, p, pl: serve_moe_layer(
            None, x, p, cfg_p, pl, top_k=1))(x, params, plan)
        np.testing.assert_allclose(y1, y2, atol=1e-5)
        assert (np.asarray(e1) == np.asarray(e2)).all()
        np.testing.assert_allclose(p1, p2, atol=1e-6)


def test_train_step_pallas_backend_matches_xla_on_mesh():
    """The jitted train step (fwd+bwd) with compute_backend="pallas" and the
    pallas dispatch backend produces the same loss and gradients as the xla
    backend on a real multi-device CPU mesh."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.data import DataConfig, SyntheticLM
        from repro.launch.mesh import mesh_context
        from repro.models import lm as lm_mod

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg_x = get_config("gpt2-moe").smoke()
        cfg_p = dataclasses.replace(
            cfg_x, moe=dataclasses.replace(cfg_x.moe,
                                           compute_backend="pallas"))
        dc = DataConfig(vocab_size=cfg_x.vocab_size, seq_len=32,
                        global_batch=8)
        batch = {k: jnp.asarray(v)
                 for k, v in SyntheticLM(dc).batch(0).items()}
        params = lm_mod.init_params(cfg_x, jax.random.PRNGKey(0))

        def loss_fn(cfg, db):
            def f(p, b):
                return lm_mod.forward_train(mesh, cfg, p, b, fsdp=False,
                                            dispatch_backend=db).loss
            return f

        with mesh_context(mesh):
            lx, gx = jax.jit(jax.value_and_grad(
                loss_fn(cfg_x, "scatter")))(params, batch)
            lp, gp = jax.jit(jax.value_and_grad(
                loss_fn(cfg_p, "pallas")))(params, batch)
        assert abs(float(lx) - float(lp)) < 1e-5, (float(lx), float(lp))
        for a, b in zip(jax.tree.leaves(gx), jax.tree.leaves(gp)):
            d = float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            assert d < 2e-4, d

        # one full optimizer step on each backend stays in tolerance too
        from repro.launch.steps import make_train_step
        from repro.optim.adamw import AdamWConfig, init_opt_state
        ocfg = AdamWConfig()
        opt = init_opt_state(params, ocfg)
        with mesh_context(mesh):
            px, _, mx = jax.jit(make_train_step(
                cfg_x, mesh, ocfg, fsdp=False))(params, opt, batch)
            pp, _, mp = jax.jit(make_train_step(
                cfg_p, mesh, ocfg, fsdp=False,
                dispatch_backend="pallas"))(params, opt, batch)
        assert abs(mx["loss"] - mp["loss"]) < 1e-5
        for a, b in zip(jax.tree.leaves(px), jax.tree.leaves(pp)):
            d = float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            assert d < 1e-4, d
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, f"stderr:\n{p.stderr[-3000:]}"
    assert "OK" in p.stdout
