"""Tests for the static contract checker (repro.analysis).

Covers: pass-1 checks against synthetic bad kernels (misaligned block,
over-budget footprint, uncovered grid, unregistered site), pass-2 lints
against synthetic shard_map bodies (unbound axis, axis literal, dropped
ordering token), the no-finding path on known-good inputs, agreement
between the committed ANALYSIS_BASELINE.json and the live repo, the
call-time VMEM asserts in kernels/dispatch.py matching the analyzer's
estimates, the bench-row annotation, and the retrace detector.
"""
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (load_baseline, new_findings, run_all,
                            write_baseline)
from repro.analysis.collectives import analyze_collectives
from repro.analysis.findings import Finding
from repro.analysis.kernels import (CONST, Block, RegistryEntry, ShapeCase,
                                    SiteEval, analyze_kernels,
                                    annotate_bench_rows, build_cases,
                                    grid_dim, iter_pallas_sites)
from repro.analysis.retrace import (RetraceError, no_retrace, supported)
from repro.kernels.dispatch import (combine_rows, combine_vmem_bytes,
                                    dispatch_rows, dispatch_vmem_bytes,
                                    invert_slots)
from repro.kernels.tiling import VMEM_BUDGET_BYTES, block_and_pad

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------ pass 1 synthetic --

_SYN_KERNEL = textwrap.dedent('''
    from jax.experimental import pallas as pl

    def bad_misaligned(x):
        return pl.pallas_call(
            _k, grid=(4,),
            in_specs=[pl.BlockSpec((12, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((12, 128), lambda i: (i, 0)),
            out_shape=None)(x)

    def bad_overbudget(x):
        return pl.pallas_call(
            _k, grid=(2,),
            in_specs=[pl.BlockSpec((4096, 2048), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=None)(x)

    def bad_uncovered(x):
        return pl.pallas_call(
            _k, grid=(3,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=None)(x)

    def good_kernel(x):
        return pl.pallas_call(
            _k, grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=None)(x)

    def not_in_registry(x):
        return pl.pallas_call(_k, grid=(1,))(x)
''')


def _syn_eval(name, inputs, outputs, grid):
    def fn(_case=None):
        return [SiteEval("syn.py", name, "syn", grid, inputs, outputs)]
    return RegistryEntry(fn, per_case=False)


_SYN_REGISTRY = {
    ("syn.py", "bad_misaligned"): _syn_eval(
        "bad_misaligned",
        [Block("a", (12, 128), "float32", (grid_dim(0), CONST), (48, 128))],
        [Block("o", (12, 128), "float32", (grid_dim(0), CONST), (48, 128))],
        (4,)),
    ("syn.py", "bad_overbudget"): _syn_eval(
        "bad_overbudget",
        [Block("big", (4096, 2048), "float32", (CONST, CONST),
               (4096, 2048))],
        [Block("o", (8, 128), "float32", (grid_dim(0), CONST), (16, 128))],
        (2,)),
    ("syn.py", "bad_uncovered"): _syn_eval(
        "bad_uncovered",
        [Block("a", (8, 128), "float32", (grid_dim(0), CONST), (32, 128))],
        [Block("o", (8, 128), "float32", (grid_dim(0), CONST), (32, 128))],
        (3,)),
    ("syn.py", "good_kernel"): _syn_eval(
        "good_kernel",
        [Block("a", (8, 128), "float32", (grid_dim(0), CONST), (32, 128))],
        [Block("o", (8, 128), "float32", (grid_dim(0), CONST), (32, 128))],
        (4,)),
}


@pytest.fixture()
def syn_kernels(tmp_path):
    (tmp_path / "syn.py").write_text(_SYN_KERNEL)
    return analyze_kernels(str(tmp_path), registry=_SYN_REGISTRY,
                           rel_prefix="syn")


def _cats(findings, qualname):
    return sorted({f.category for f in findings if f.qualname == qualname})


def test_misaligned_block_detected(syn_kernels):
    assert _cats(syn_kernels, "bad_misaligned") == ["misaligned-block"]
    f = next(f for f in syn_kernels if f.qualname == "bad_misaligned")
    assert "12" in f.message and "8" in f.message  # size vs sublane tile


def test_overbudget_footprint_detected(syn_kernels):
    fs = [f for f in syn_kernels if f.qualname == "bad_overbudget"]
    assert _cats(syn_kernels, "bad_overbudget") == ["vmem-over-budget"]
    f = fs[0]
    # resident big block once + streamed out twice
    expect = 4096 * 2048 * 4 + 2 * (8 * 128 * 4)
    assert f.data["footprint_bytes"] == expect
    assert f.data["budget_bytes"] == VMEM_BUDGET_BYTES


def test_uncovered_grid_detected(syn_kernels):
    assert _cats(syn_kernels, "bad_uncovered") == ["grid-uncovered"]


def test_good_kernel_no_findings(syn_kernels):
    assert _cats(syn_kernels, "good_kernel") == []


def test_unregistered_site_detected(syn_kernels):
    assert _cats(syn_kernels, "not_in_registry") == ["unregistered-kernel"]


def test_stale_registry_entry_detected(tmp_path):
    (tmp_path / "syn.py").write_text(_SYN_KERNEL)
    reg = dict(_SYN_REGISTRY)
    reg[("syn.py", "vanished_kernel")] = _syn_eval(
        "vanished_kernel", [], [], (1,))
    fs = analyze_kernels(str(tmp_path), registry=reg, rel_prefix="syn")
    assert _cats(fs, "vanished_kernel") == ["missing-kernel"]


def test_ast_site_enumeration(tmp_path):
    (tmp_path / "syn.py").write_text(_SYN_KERNEL)
    sites = iter_pallas_sites(str(tmp_path), rel_prefix="syn")
    assert {s.qualname for s in sites} == {
        "bad_misaligned", "bad_overbudget", "bad_uncovered", "good_kernel",
        "not_in_registry"}
    by_name = {s.qualname: s for s in sites}
    assert by_name["good_kernel"].grid_len == 1
    assert by_name["good_kernel"].n_in_specs == 1


# ------------------------------------------------------ pass 2 synthetic --

_SYN_COLLECTIVES = textwrap.dedent('''
    """Docstrings may mention the model axis freely."""
    from jax import lax
    from repro.core.axes import EP_AXIS

    def ok_constant(x):
        return lax.psum(x, EP_AXIS)

    def bad_unbound(x):
        return lax.psum(x, "not_a_mesh_axis")

    def bad_param(x, ax):
        return lax.axis_index(ax)

    def caller(x):
        return bad_param(x, "typoed")

    def bad_literal_spec():
        return ("data", "model")

    def pipelined_expert_ffn(x):
        return x, object()

    def drops_token(x):
        out, _ = pipelined_expert_ffn(x)
        return out

    def keeps_token(x):
        out, tok = pipelined_expert_ffn(x)
        return out, tok
''')


@pytest.fixture()
def syn_collectives(tmp_path):
    (tmp_path / "mod.py").write_text(_SYN_COLLECTIVES)
    return analyze_collectives(str(tmp_path), rel_prefix="syn",
                               producers={"pipelined_expert_ffn": 1})


def test_unbound_axis_detected(syn_collectives):
    keys = {f.key for f in syn_collectives if f.category == "unbound-axis"}
    assert "psum:not_a_mesh_axis" in keys
    # parameterized axis resolved through its in-module call site
    assert "axis_index:typoed" in keys


def test_axis_literal_detected(syn_collectives):
    vals = {f.key.split("@")[0] for f in syn_collectives
            if f.category == "axis-literal"}
    assert vals == {"data", "model"}   # docstring mention exempt


def test_dropped_token_detected(syn_collectives):
    drops = [f for f in syn_collectives
             if f.category == "dropped-ordering-token"]
    assert [f.qualname for f in drops] == ["drops_token"]


def test_bound_axis_and_kept_token_clean(syn_collectives):
    assert not any(f.qualname in ("ok_constant", "keeps_token")
                   for f in syn_collectives)


def test_real_tree_collectives_clean():
    assert analyze_collectives(os.path.join(REPO, "src", "repro")) == []


# --------------------------------------------------- repo vs baseline -----

def test_repo_findings_match_committed_baseline():
    """CI's gate, as a test: the live tree produces exactly the findings
    recorded in ANALYSIS_BASELINE.json — nothing new, nothing stale."""
    findings = run_all(REPO)
    baseline = load_baseline(os.path.join(REPO, "ANALYSIS_BASELINE.json"))
    fresh = new_findings(findings, baseline)
    assert fresh == [], [f.fingerprint for f in fresh]
    current = {f.fingerprint for f in findings}
    stale = baseline - current
    assert stale == set(), sorted(stale)


def test_pr4_ceilings_are_retired():
    """The PR-4 VMEM ceilings (T-resident dispatch source, R-resident
    combine buffer, full-K grouped_matmul blocks) are gone: re-tiling
    removed every untiled-block and vmem-over-budget finding, and every
    registered kernel's static footprint fits the per-core budget at all
    paper shapes, scale 1 included."""
    findings = run_all(REPO)
    cats = {f.category for f in findings}
    assert "untiled-block" not in cats, \
        [f.fingerprint for f in findings if f.category == "untiled-block"]
    assert "vmem-over-budget" not in cats, \
        [f.fingerprint for f in findings
         if f.category == "vmem-over-budget"]
    from repro.analysis.kernels import REGISTRY
    for entry in REGISTRY.values():
        cases = build_cases() if entry.per_case else [None]
        for case in cases:
            for ev in entry.eval_fn(case):
                assert ev.footprint() <= VMEM_BUDGET_BYTES, \
                    (ev.qualname, ev.variant, ev.case, ev.footprint())


def test_injected_bad_kernel_fails_gate(tmp_path):
    """A misaligned synthetic kernel makes the baseline-gated run fail."""
    findings = run_all(REPO)
    base = tmp_path / "baseline.json"
    write_baseline(str(base), findings)
    assert new_findings(findings, load_baseline(str(base))) == []
    injected = findings + [Finding(
        "misaligned-block", "src/repro/kernels/new.py", "new_kernel",
        "a[dim1]", "synthetic")]
    assert len(new_findings(injected, load_baseline(str(base)))) == 1


# ------------------------------------- dispatch call-time VMEM asserts ----

def test_dispatch_assert_matches_analyzer_estimate():
    t, d, r, k = 64, 128, 32, 2
    x = jnp.ones((t, d), jnp.float32)
    rows = jnp.zeros((t, k), jnp.int32)
    src, _ = invert_slots(rows, r)
    br, _ = block_and_pad(r, 1024)
    bx, _ = block_and_pad(t, 512)
    expect = dispatch_vmem_bytes(br, bx, d)
    with pytest.raises(ValueError) as ei:
        dispatch_rows(x, src, vmem_budget=expect - 1)
    assert f"{expect:,} B" in str(ei.value)
    # at exactly the footprint the call goes through
    out = dispatch_rows(x, src, vmem_budget=expect)
    assert out.shape == (r, d)

    buf = jnp.ones((r, d), jnp.float32)
    w = jnp.ones((t, k), jnp.float32)
    bt, _ = block_and_pad(t, 1024)
    brf, _ = block_and_pad(r, 512)
    expect_c = combine_vmem_bytes(bt, brf, d, k)
    with pytest.raises(ValueError) as ei:
        combine_rows(buf, rows, w, vmem_budget=expect_c - 1)
    assert f"{expect_c:,} B" in str(ei.value)
    assert combine_rows(buf, rows, w, vmem_budget=expect_c).shape == (t, d)


def test_registry_estimates_match_call_time_asserts():
    """The analyzer's SiteEval footprints equal the dispatch.py formulas
    at every paper shape (asserted inside the eval fns — just drive them)."""
    from repro.analysis.kernels import (_eval_combine_rows,
                                        _eval_dispatch_rows)
    for case in build_cases():
        ev_d = _eval_dispatch_rows(case)[0]
        br, _ = block_and_pad(case.R, 1024)
        bx, _ = block_and_pad(case.T, 512)
        assert ev_d.footprint() == dispatch_vmem_bytes(br, bx, case.D)
        ev_c = _eval_combine_rows(case)[0]
        bt, _ = block_and_pad(case.T, 1024)
        brf, _ = block_and_pad(case.R, 512)
        assert ev_c.footprint() == combine_vmem_bytes(bt, brf, case.D,
                                                      case.K)


# ------------------------------------------------------ bench annotation --

def test_bench_rows_annotated():
    with open(os.path.join(REPO, "BENCH_kernels.json")) as fh:
        rows = json.load(fh)
    annotate_bench_rows(rows)
    known = [r for r in rows if r["bench"] in
             ("gating", "dispatch_combine", "routing", "grouped_ffn",
              "layer_fwdbwd")]
    assert known
    for r in known:
        assert r["static_vmem_bytes"] > 0
        assert r["vmem_budget_bytes"] == VMEM_BUDGET_BYTES
        assert r["vmem_fits"] == (r["static_vmem_bytes"]
                                  <= r["vmem_budget_bytes"])


# ---------------------------------------------------------- retrace pass --

def test_no_retrace_on_warm_function():
    f = jax.jit(lambda x: x * 3 + 1)
    x = jnp.ones((16,))
    f(x)
    with no_retrace("warm repeat") as rep:
        f(x)
        f(x)
    if supported():
        assert rep.count == 0 and rep.ok


def test_retrace_detected_on_new_shape():
    if not supported():
        pytest.skip("jax tracing counter unavailable")
    f = jax.jit(lambda x: x - 1)
    f(jnp.ones((4,)))
    with pytest.raises(RetraceError):
        with no_retrace("cold shape"):
            f(jnp.ones((32,)))


def test_retrace_nonstrict_records_without_raising():
    if not supported():
        pytest.skip("jax tracing counter unavailable")
    f = jax.jit(lambda x: x + 2)
    with no_retrace("cold start", strict=False) as rep:
        f(jnp.ones((5,)))
    assert rep.count is not None and rep.count > 0 and not rep.ok


def test_shape_cases_cover_paper_models():
    cases = build_cases()
    names = {c.name for c in cases}
    assert {"transformer-xl-moe/s1", "gpt2-moe/s4",
            "bert2gpt2-moe/s1", "bert-large-moe/s4"} <= names
    for c in cases:
        assert isinstance(c, ShapeCase)
        assert c.R == c.E * c.C and c.C % 8 == 0
