"""Serving engine: plan cache (§5.2 drift invalidation), slot capacity under
replication, continuous-batching queue/micro-batch behavior, and numerics of
the distributed dispatch path the server now routes through."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.core import init_moe_params, moe_layer
from repro.core.placement import (PlanCache, needs_finetune, plan_placement,
                                  PlacementPlan)
from repro.core.popularity import (PathProfile, estimation_accuracy,
                                   top2k_sets_match)
from repro.core.serving import PlanArrays, serve_moe_layer, slot_capacity
from repro.models import lm as lm_mod
from repro.runtime.engine import EngineConfig, ServingEngine, simulate
from repro.runtime.server import MoEServer, ServerConfig


# --- top-2k check: one implementation, pinned semantics ---------------------

def test_top2k_check_is_single_implementation():
    est = np.array([.4, .3, .1, .05, .05, .04, .03, .03])
    same = est + 1e-3
    flipped = est[::-1].copy()
    for a, b in [(est, same), (est, flipped), (same, flipped)]:
        for k in (1, 2):
            assert estimation_accuracy(a, b, k) == top2k_sets_match(a, b, k)
            assert needs_finetune(a, b, k) == (not top2k_sets_match(a, b, k))
    # set semantics: order within the top-2k does not matter
    a = np.array([.5, .3, .1, .1])
    b = np.array([.3, .5, .1, .1])           # top-2 swapped, same set
    assert top2k_sets_match(a, b, 1)
    # 2k clips at E
    assert top2k_sets_match(a, b, 8)


# --- plan cache -------------------------------------------------------------

def test_plan_cache_reuse_and_invalidation():
    e = 8
    pop = np.array([.4, .2, .1, .1, .05, .05, .05, .05])
    cache = PlanCache(top_k=1)
    assert cache.lookup(0, pop) is None              # cold miss
    plan = plan_placement(pop, e, max_pack=4)
    cache.store(0, plan)
    # same top-2k set -> hit, even with perturbed magnitudes
    assert cache.lookup(0, pop * 1.1) is plan
    # drift: a different expert enters the top-2k -> invalidate
    drifted = pop.copy()
    drifted[7] = 0.9
    assert cache.lookup(0, drifted) is None
    assert cache.stats.hits == 1
    assert cache.stats.misses == 2
    assert cache.stats.invalidations == 1
    # entry was evicted: next lookup with the original pop misses again
    assert cache.lookup(0, pop) is None
    np.testing.assert_allclose(cache.stats.reuse_rate, 1 / 4)


def test_plan_cache_is_per_layer():
    pop = np.ones(4) / 4
    cache = PlanCache(top_k=1)
    cache.store(0, plan_placement(pop, 4))
    assert cache.lookup(1, pop) is None
    assert cache.lookup(0, pop) is not None


# --- slot capacity under replication ----------------------------------------

def test_slot_capacity_shrinks_with_replication():
    assert slot_capacity(64, 1) == 64
    assert slot_capacity(64, 2) == 32        # replicated -> smaller buffers
    assert slot_capacity(64, 3) == 22        # ceil division
    assert slot_capacity(16, 4) == 8         # floored at 8
    assert slot_capacity(24, 0) == 24        # degenerate guard


def test_serve_layer_replicated_buffers_match_unreplicated():
    """End-to-end regression: a fully-replicated plan served with shrunken
    per-slot buffers (min_replicas=2) matches the min_replicas=1 numerics
    and the reference training layer."""
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=32, capacity_factor=4.0)
    params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    # uniform popularity over 4 experts on 8 devices -> every expert gets
    # 2 replicas (Eq. 1: n_e = 8 * 0.25 = 2)
    plan = plan_placement(np.ones(4) / 4, 8, max_pack=4)
    assert int(plan.n_replicas.min()) == 2
    pa = PlanArrays.from_plan(plan)
    y1, _, _ = serve_moe_layer(None, x, params, cfg, pa, top_k=1,
                               min_replicas=1)
    y2, _, _ = serve_moe_layer(None, x, params, cfg, pa, top_k=1,
                               min_replicas=2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    ref = moe_layer(None, x.reshape(4, 16, 16), params, cfg, lina=False,
                    top_k=1).y.reshape(64, 16)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(ref), atol=1e-4)


# --- server: plan cache wired into the serve loop ---------------------------

def _smoke_server(policy="lina", plan_cache=True, capacity_factor=None):
    cfg = get_config("gpt2-moe").smoke()
    if capacity_factor is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=capacity_factor))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    scfg = ServerConfig(path_len=2, schedule_policy=policy,
                        plan_cache=plan_cache)
    return cfg, MoEServer(cfg, params, prof, scfg)


def test_server_plan_cache_amortizes_across_batches():
    cfg, server = _smoke_server()
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 16))
    _, stats1 = server.serve(toks)
    assert not any(s.plan_reused for s in stats1)    # cold caches
    _, stats2 = server.serve(toks)                   # identical traffic
    assert all(s.plan_reused for s in stats2)        # full reuse
    st = server.plan_cache.stats
    assert st.hits == len(stats2) and st.misses == len(stats1)


def test_server_without_plan_cache_never_reuses():
    cfg, server = _smoke_server(plan_cache=False)
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 16))
    for _ in range(2):
        _, stats = server.serve(toks)
        assert not any(s.plan_reused for s in stats)
    assert server.plan_cache is None


def test_server_config_default_not_shared():
    cfg = get_config("gpt2-moe").smoke()
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    a = MoEServer(cfg, params, prof)
    b = MoEServer(cfg, params, prof)
    assert a.scfg is not b.scfg                      # no shared default


# --- continuous-batching engine ---------------------------------------------

def test_engine_microbatch_formation_token_budget():
    cfg, server = _smoke_server()
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=32,
                                             max_batch_requests=8))
    rng = np.random.RandomState(0)
    for _ in range(5):
        eng.submit(rng.randint(0, cfg.vocab_size, (16,)), arrival=0.0)
    batch = eng._form_microbatch()
    assert len(batch) == 2                           # 2 * 16 fills the budget
    assert [r.rid for r in batch] == [0, 1]          # FCFS
    assert eng.pending() == 3
    # an over-budget single request still progresses
    eng2 = ServingEngine(server, EngineConfig(max_batch_tokens=8))
    eng2.submit(rng.randint(0, cfg.vocab_size, (16,)), arrival=0.0)
    assert len(eng2._form_microbatch()) == 1


def test_engine_serves_requests_and_matches_server():
    cfg, server = _smoke_server(capacity_factor=16.0)
    toks = np.random.RandomState(1).randint(0, cfg.vocab_size, (1, 16))
    ref_logits, _ = server.serve(toks)

    cfg2, server2 = _smoke_server(capacity_factor=16.0)
    eng = ServingEngine(server2, EngineConfig(max_batch_tokens=16))
    eng.submit(toks[0], arrival=0.0)
    results = eng.run()
    assert len(results) == 1
    np.testing.assert_allclose(results[0].logits, ref_logits[0],
                               atol=1e-4, rtol=1e-4)
    assert results[0].n_tokens == 16
    assert np.isfinite(results[0].logits).all()


def test_engine_ragged_batch_and_path_state():
    cfg, server = _smoke_server()
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64))
    rng = np.random.RandomState(2)
    r1 = eng.submit(rng.randint(0, cfg.vocab_size, (16,)), arrival=0.0)
    r2 = eng.submit(rng.randint(0, cfg.vocab_size, (9,)), arrival=0.0)
    results = eng.step(now=0.0)
    assert sorted(r.rid for r in results) == [r1, r2]
    by_rid = {r.rid: r for r in results}
    assert by_rid[r2].n_tokens == 9
    # per-request rolling path state persisted, sized to the request
    ps1 = eng.request_path_state(r1)
    ps2 = eng.request_path_state(r2)
    assert ps1.shape == (16,) and ps2.shape == (9,)
    assert (ps1 < server.profile.n_buckets).all()
    # a follow-up request carries its stream's rolling path state
    r3 = eng.submit(np.zeros(9, np.int64), arrival=1.0, prev_rid=r2)
    np.testing.assert_array_equal(eng.request_path_state(r3), ps2)
    results2 = eng.step(now=1.0)
    assert len(results2) == 1 and np.isfinite(results2[0].logits).all()
    # ... and its own final state differs from the seed after serving
    assert eng.request_path_state(r3).shape == (9,)


def test_engine_padding_rows_do_not_change_logits():
    """Bucketing 5 requests to 8 rows (3 all-pad rows) must not perturb the
    real requests' logits at the default capacity factor: capacity is sized
    from valid tokens and pad rows sort after real rows in slot order."""
    cfg, server = _smoke_server()
    rng = np.random.RandomState(5)
    reqs = [rng.randint(0, cfg.vocab_size, (12,)) for _ in range(5)]
    _, server_direct = _smoke_server()
    direct = server_direct.serve_batch(np.stack(reqs))
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=60,
                                             max_batch_requests=5))
    rids = [eng.submit(r, arrival=0.0) for r in reqs]
    results = {r.rid: r for r in eng.step(now=0.0)}
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(results[rid].logits, direct.logits[i],
                                   atol=1e-4, rtol=1e-4)


def test_engine_simulate_open_loop_latency():
    cfg, server = _smoke_server()
    rng = np.random.RandomState(3)
    toks = rng.randint(0, cfg.vocab_size, (16,))
    # steady traffic: identical requests -> stable popularity -> plan reuse
    trace = [(toks, 0.01 * i) for i in range(6)]
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=32))
    results = simulate(eng, trace)
    assert len(results) == 6
    assert all(r.latency >= 0 for r in results)
    assert all(r.completion >= r.arrival for r in results)
    # steady traffic + cached plans => some reuse after the first batch
    assert eng.plan_reuse_rate > 0.0
