"""Serving engine: plan cache (§5.2 drift invalidation), slot capacity under
replication, continuous-batching queue/micro-batch behavior, numerics of
the distributed dispatch path the server routes through, and the
prefill/decode split (incremental KV-cache decoding must reproduce full
re-prefill logits, and the engine must never re-run prefill mid-decode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, get_config
from repro.configs.base import MoEConfig
from repro.core import init_moe_params, moe_layer
from repro.core.placement import (PlanCache, identity_plan, needs_finetune,
                                  plan_placement, PlacementPlan)
from repro.core.popularity import (PathProfile, estimation_accuracy,
                                   top2k_sets_match)
from repro.core.serving import (PlanArrays, serve_moe_layer, slot_capacity,
                                stack_plan_arrays)
from repro.models import lm as lm_mod
from repro.runtime.engine import EngineConfig, ServingEngine, simulate
from repro.runtime.server import MoEServer, ServerConfig


# --- top-2k check: one implementation, pinned semantics ---------------------

def test_top2k_check_is_single_implementation():
    est = np.array([.4, .3, .1, .05, .05, .04, .03, .03])
    same = est + 1e-3
    flipped = est[::-1].copy()
    for a, b in [(est, same), (est, flipped), (same, flipped)]:
        for k in (1, 2):
            assert estimation_accuracy(a, b, k) == top2k_sets_match(a, b, k)
            assert needs_finetune(a, b, k) == (not top2k_sets_match(a, b, k))
    # set semantics: order within the top-2k does not matter
    a = np.array([.5, .3, .1, .1])
    b = np.array([.3, .5, .1, .1])           # top-2 swapped, same set
    assert top2k_sets_match(a, b, 1)
    # 2k clips at E
    assert top2k_sets_match(a, b, 8)


# --- plan cache -------------------------------------------------------------

def test_plan_cache_reuse_and_invalidation():
    e = 8
    pop = np.array([.4, .2, .1, .1, .05, .05, .05, .05])
    cache = PlanCache(top_k=1)
    assert cache.lookup(0, pop) is None              # cold miss
    plan = plan_placement(pop, e, max_pack=4)
    cache.store(0, plan)
    # same top-2k set -> hit, even with perturbed magnitudes
    assert cache.lookup(0, pop * 1.1) is plan
    # drift: a different expert enters the top-2k -> invalidate
    drifted = pop.copy()
    drifted[7] = 0.9
    assert cache.lookup(0, drifted) is None
    assert cache.stats.hits == 1
    assert cache.stats.misses == 2
    assert cache.stats.invalidations == 1
    # entry was evicted: next lookup with the original pop misses again
    assert cache.lookup(0, pop) is None
    np.testing.assert_allclose(cache.stats.reuse_rate, 1 / 4)


def test_plan_cache_is_per_layer():
    pop = np.ones(4) / 4
    cache = PlanCache(top_k=1)
    cache.store(0, plan_placement(pop, 4))
    assert cache.lookup(1, pop) is None
    assert cache.lookup(0, pop) is not None


# --- slot capacity under replication ----------------------------------------

def test_slot_capacity_shrinks_with_replication():
    assert slot_capacity(64, 1) == 64
    assert slot_capacity(64, 2) == 32        # replicated -> smaller buffers
    assert slot_capacity(64, 3) == 22        # ceil division
    assert slot_capacity(16, 4) == 8         # floored at 8
    assert slot_capacity(24, 0) == 24        # degenerate guard


def test_serve_layer_replicated_buffers_match_unreplicated():
    """End-to-end regression: a fully-replicated plan served with shrunken
    per-slot buffers (min_replicas=2) matches the min_replicas=1 numerics
    and the reference training layer."""
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=32, capacity_factor=4.0)
    params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    # uniform popularity over 4 experts on 8 devices -> every expert gets
    # 2 replicas (Eq. 1: n_e = 8 * 0.25 = 2)
    plan = plan_placement(np.ones(4) / 4, 8, max_pack=4)
    assert int(plan.n_replicas.min()) == 2
    pa = PlanArrays.from_plan(plan)
    y1, _, _ = serve_moe_layer(None, x, params, cfg, pa, top_k=1,
                               min_replicas=1)
    y2, _, _ = serve_moe_layer(None, x, params, cfg, pa, top_k=1,
                               min_replicas=2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    ref = moe_layer(None, x.reshape(4, 16, 16), params, cfg, lina=False,
                    top_k=1).y.reshape(64, 16)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(ref), atol=1e-4)


# --- server: plan cache wired into the serve loop ---------------------------

def _smoke_server(policy="lina", plan_cache=True, capacity_factor=None):
    cfg = get_config("gpt2-moe").smoke()
    if capacity_factor is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=capacity_factor))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    scfg = ServerConfig(path_len=2, schedule_policy=policy,
                        plan_cache=plan_cache)
    return cfg, MoEServer(cfg, params, prof, scfg)


def test_server_plan_cache_amortizes_across_batches():
    cfg, server = _smoke_server()
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 16))
    _, stats1 = server.serve(toks)
    assert not any(s.plan_reused for s in stats1)    # cold caches
    _, stats2 = server.serve(toks)                   # identical traffic
    assert all(s.plan_reused for s in stats2)        # full reuse
    st = server.plan_cache.stats
    assert st.hits == len(stats2) and st.misses == len(stats1)


def test_server_without_plan_cache_never_reuses():
    cfg, server = _smoke_server(plan_cache=False)
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 16))
    for _ in range(2):
        _, stats = server.serve(toks)
        assert not any(s.plan_reused for s in stats)
    assert server.plan_cache is None


def test_server_config_default_not_shared():
    cfg = get_config("gpt2-moe").smoke()
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    a = MoEServer(cfg, params, prof)
    b = MoEServer(cfg, params, prof)
    assert a.scfg is not b.scfg                      # no shared default


# --- continuous-batching engine ---------------------------------------------

def test_engine_microbatch_formation_token_budget():
    cfg, server = _smoke_server()
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=32,
                                             max_batch_requests=8))
    rng = np.random.RandomState(0)
    for _ in range(5):
        eng.submit(rng.randint(0, cfg.vocab_size, (16,)), arrival=0.0)
    batch = eng._form_microbatch()
    assert len(batch) == 2                           # 2 * 16 fills the budget
    assert [r.rid for r in batch] == [0, 1]          # FCFS
    assert eng.pending() == 3
    # an over-budget single request still progresses
    eng2 = ServingEngine(server, EngineConfig(max_batch_tokens=8))
    eng2.submit(rng.randint(0, cfg.vocab_size, (16,)), arrival=0.0)
    assert len(eng2._form_microbatch()) == 1


def test_engine_serves_requests_and_matches_server():
    cfg, server = _smoke_server(capacity_factor=16.0)
    toks = np.random.RandomState(1).randint(0, cfg.vocab_size, (1, 16))
    ref_logits, _ = server.serve(toks)

    cfg2, server2 = _smoke_server(capacity_factor=16.0)
    eng = ServingEngine(server2, EngineConfig(max_batch_tokens=16))
    eng.submit(toks[0], arrival=0.0)
    results = eng.run()
    assert len(results) == 1
    np.testing.assert_allclose(results[0].logits, ref_logits[0],
                               atol=1e-4, rtol=1e-4)
    assert results[0].n_tokens == 16
    assert np.isfinite(results[0].logits).all()


def test_engine_ragged_batch_and_path_state():
    cfg, server = _smoke_server()
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64))
    rng = np.random.RandomState(2)
    r1 = eng.submit(rng.randint(0, cfg.vocab_size, (16,)), arrival=0.0)
    r2 = eng.submit(rng.randint(0, cfg.vocab_size, (9,)), arrival=0.0)
    results = eng.step(now=0.0)
    assert sorted(r.rid for r in results) == [r1, r2]
    by_rid = {r.rid: r for r in results}
    assert by_rid[r2].n_tokens == 9
    # per-request rolling path state persisted, sized to the request
    ps1 = eng.request_path_state(r1)
    ps2 = eng.request_path_state(r2)
    assert ps1.shape == (16,) and ps2.shape == (9,)
    assert (ps1 < server.profile.n_buckets).all()
    # a follow-up request carries its stream's rolling path state
    r3 = eng.submit(np.zeros(9, np.int64), arrival=1.0, prev_rid=r2)
    np.testing.assert_array_equal(eng.request_path_state(r3), ps2)
    results2 = eng.step(now=1.0)
    assert len(results2) == 1 and np.isfinite(results2[0].logits).all()
    # ... and its own final state differs from the seed after serving
    assert eng.request_path_state(r3).shape == (9,)


def test_engine_padding_rows_do_not_change_logits():
    """Bucketing 5 requests to 8 rows (3 all-pad rows) must not perturb the
    real requests' logits at the default capacity factor: capacity is sized
    from valid tokens and pad rows sort after real rows in slot order."""
    cfg, server = _smoke_server()
    rng = np.random.RandomState(5)
    reqs = [rng.randint(0, cfg.vocab_size, (12,)) for _ in range(5)]
    _, server_direct = _smoke_server()
    direct = server_direct.serve_batch(np.stack(reqs))
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=60,
                                             max_batch_requests=5))
    rids = [eng.submit(r, arrival=0.0) for r in reqs]
    results = {r.rid: r for r in eng.step(now=0.0)}
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(results[rid].logits, direct.logits[i],
                                   atol=1e-4, rtol=1e-4)


# --- incremental decode: prefill + decode_batch vs full re-prefill ----------

def test_prefill_then_decode_matches_full_serve():
    """The distributed analog of test_decode_matches_prefill: prefill the
    first 8 tokens, then 4 incremental decode_batch steps (each one token
    per request through the per-layer two-phase core) must reproduce the
    full 12-token re-prefill logits."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    rng = np.random.RandomState(11)
    toks = rng.randint(0, cfg.vocab_size, (2, 12))
    _, ref_server = _smoke_server(capacity_factor=16.0)
    ref = ref_server.serve_batch(toks)

    pre = server.prefill_batch(toks[:, :8], cache_len=12)
    logits, cache, path = pre.logits, pre.cache, pre.path_ids[:, 7]
    for i in range(8, 12):
        dec = server.decode_batch(toks[:, i], cache, path)
        logits, cache, path = dec.logits, dec.cache, dec.path_state
        assert len(dec.stats) == cfg.n_moe_layers   # two-phase core per layer
    np.testing.assert_allclose(logits, ref.logits, atol=1e-3, rtol=1e-3)
    assert (np.asarray(cache.pos) == 12).all()
    # the rolling path state kept advancing during decode
    assert (path < server.profile.n_buckets).all()


def test_prefill_batch_matches_serve_batch_logits():
    """Cache capture must not perturb the forward numerics."""
    cfg, server = _smoke_server()
    toks = np.random.RandomState(12).randint(0, cfg.vocab_size, (2, 10))
    _, ref_server = _smoke_server()
    ref = ref_server.serve_batch(toks)
    pre = server.prefill_batch(toks, cache_len=16)
    np.testing.assert_allclose(pre.logits, ref.logits, atol=1e-5)
    np.testing.assert_array_equal(pre.path_ids, ref.path_ids)
    assert pre.cache.kv.k.shape[3] == 16            # [G, every, B, cap, ...]


def test_decode_batch_padding_rows_are_inert():
    """Bucketed decode batches carry all-padding rows; they must not change
    valid rows' logits (capacity is sized from valid tokens)."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    rng = np.random.RandomState(13)
    toks = rng.randint(0, cfg.vocab_size, (2, 8))
    pre = server.prefill_batch(toks, cache_len=10)
    dec = server.decode_batch(toks[:, -1] * 0 + 7, pre.cache,
                              pre.path_ids[:, -1])

    _, server2 = _smoke_server(capacity_factor=16.0)
    pre2 = server2.prefill_batch(toks, cache_len=10)
    k, v = pre2.cache.kv.k, pre2.cache.kv.v
    pad = jnp.zeros_like(k[:, :, :1])
    cache4 = lm_mod.LMCache(
        lm_mod.KVCache(jnp.concatenate([k, pad, pad], axis=2),
                       jnp.concatenate([v, pad, pad], axis=2)),
        None, None, jnp.concatenate([pre2.cache.pos,
                                     jnp.zeros((2,), jnp.int32)]))
    dec4 = server2.decode_batch(
        np.array([7, 7, 0, 0]), cache4,
        np.concatenate([np.asarray(pre2.path_ids[:, -1]), [0, 0]]),
        valid=np.array([True, True, False, False]))
    np.testing.assert_allclose(dec4.logits[:2], dec.logits, atol=1e-4,
                               rtol=1e-4)


# --- stacked per-layer plans through decode_step -----------------------------

def test_decode_step_stacked_plans_and_expert_choices():
    """decode_step must accept one plan per MoE layer (stacked PlanArrays)
    and surface per-layer top-1 expert choices; heterogeneous placements
    must not change logits (plans move experts, not math)."""
    cfg = REGISTRY["mixtral-8x22b"].smoke()
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(1))
    b = 2
    cache = lm_mod.init_cache(cfg, b, 8, jnp.float32)
    tok = jnp.zeros((b,), jnp.int32)
    e = cfg.moe.n_experts
    n_groups = cfg.n_layers // cfg.moe.every

    single = PlanArrays.from_plan(identity_plan(e, e, max_pack=2))
    l1, _, e1 = lm_mod.decode_step(None, cfg, params, cache, tok,
                                   serve_plan=single, serve_top_k=1)
    same = stack_plan_arrays([identity_plan(e, e, max_pack=2)] * n_groups)
    assert same.stacked and same.slot_expert.shape[0] == n_groups
    l2, _, e2 = lm_mod.decode_step(None, cfg, params, cache, tok,
                                   serve_plan=same, serve_top_k=1)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    assert e1.shape == (n_groups, b)

    skew = [plan_placement(np.roll([.7, .1, .1, .1], i), e, max_pack=2)
            for i in range(n_groups)]
    l3, _, e3 = lm_mod.decode_step(None, cfg, params, cache, tok,
                                   serve_plan=stack_plan_arrays(skew),
                                   serve_top_k=1)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l3), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e3))


# --- engine generation lifecycle ---------------------------------------------

def _counting_server(server):
    calls = {"prefill": 0, "decode": 0, "serve": 0, "decode_tokens": []}
    orig_p, orig_d, orig_s = (server.prefill_batch, server.decode_batch,
                              server.serve_batch)

    def prefill(*a, **k):
        calls["prefill"] += 1
        return orig_p(*a, **k)

    def decode(tokens, *a, **k):
        calls["decode"] += 1
        calls["decode_tokens"].append(np.asarray(tokens).size)
        return orig_d(tokens, *a, **k)

    def serve(*a, **k):
        calls["serve"] += 1
        return orig_s(*a, **k)

    server.prefill_batch = prefill
    server.decode_batch = decode
    server.serve_batch = serve
    return calls


def test_engine_decoding_never_reruns_prefill():
    """A generating request prefills exactly once; every later step is a
    single-token decode whose batch size is the number of in-flight
    requests — per-output-token cost independent of prompt length."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    calls = _counting_server(server)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64))
    rng = np.random.RandomState(21)
    eng.submit(rng.randint(0, cfg.vocab_size, (24,)), arrival=0.0,
               max_new_tokens=5)
    results = eng.run()
    assert len(results) == 1
    assert calls["prefill"] == 1 and calls["serve"] == 0
    assert calls["decode"] == 4                      # 5 tokens: 1 + 4 steps
    assert all(n == 1 for n in calls["decode_tokens"])   # never the prompt
    r = results[0]
    assert r.n_generated == 5 and r.tokens.shape == (5,)
    assert r.ttft is not None and r.ttft <= r.completion
    assert (r.tokens < cfg.vocab_size).all() and np.isfinite(r.logits).all()


def test_engine_generation_matches_manual_decode():
    cfg, server = _smoke_server(capacity_factor=16.0)
    rng = np.random.RandomState(22)
    toks = rng.randint(0, cfg.vocab_size, (10,))
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=32))
    eng.submit(toks, arrival=0.0, max_new_tokens=4)
    out = eng.run()[0]

    _, ref = _smoke_server(capacity_factor=16.0)
    pre = ref.prefill_batch(toks[None], cache_len=14)
    cur, gen = int(np.argmax(pre.logits[0])), []
    gen.append(cur)
    cache, path = pre.cache, pre.path_ids[:, -1]
    for _ in range(3):
        dec = ref.decode_batch([cur], cache, path)
        cur = int(np.argmax(dec.logits[0]))
        gen.append(cur)
        cache, path = dec.cache, dec.path_state
    np.testing.assert_array_equal(out.tokens, gen)


def test_engine_mixes_decodes_with_new_prefills():
    """An in-flight decode and a newly arrived prefill share one step."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    calls = _counting_server(server)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64))
    rng = np.random.RandomState(23)
    r1 = eng.submit(rng.randint(0, cfg.vocab_size, (8,)), arrival=0.0,
                    max_new_tokens=3)
    eng.step(now=0.0)                                # prefill r1
    assert eng.active() == 1 and calls["prefill"] == 1
    r2 = eng.submit(rng.randint(0, cfg.vocab_size, (8,)), arrival=0.1,
                    max_new_tokens=2)
    eng.step(now=0.1)                # decode r1 AND prefill r2 in one step
    assert calls["prefill"] == 2 and calls["decode"] == 1
    assert eng.active() == 2
    results = eng.run()
    assert sorted(r.rid for r in results) == [r1, r2]
    assert {r.rid: r.n_generated for r in results} == {r1: 3, r2: 2}


def test_engine_mixed_score_and_generation_batch():
    """Score-only and generating requests admitted in the same step run as
    separate forwards: the score-only row completes via serve_batch (no
    cache allocated for it), the generating row prefills with a cache
    sized only to ITS prompt + budget."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    calls = _counting_server(server)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64))
    rng = np.random.RandomState(26)
    rg = eng.submit(rng.randint(0, cfg.vocab_size, (8,)), arrival=0.0,
                    max_new_tokens=2)
    rs = eng.submit(rng.randint(0, cfg.vocab_size, (12,)), arrival=0.0)
    done = eng.step(now=0.0)
    assert calls["serve"] == 1 and calls["prefill"] == 1
    assert [r.rid for r in done] == [rs]              # score-only finishes
    assert done[0].tokens is None
    results = eng.run()
    assert results[0].rid == rg and results[0].n_generated == 2


def test_engine_state_cache_never_evicts_active_requests():
    """state_cache overflow must not drop the path state of a request that
    is still mid-decode (satellite guard)."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64,
                                             state_cache=2))
    rng = np.random.RandomState(24)
    ra = eng.submit(rng.randint(0, cfg.vocab_size, (6,)), arrival=0.0,
                    max_new_tokens=8)
    eng.step(now=0.0)                                 # ra enters decode
    assert eng.active() == 1
    for i in range(4):                # churn completed states past the cap
        eng.submit(rng.randint(0, cfg.vocab_size, (6,)), arrival=0.1 + i)
        eng.step(now=0.1 + i)
    assert len(eng._path_states) <= 2 + 1             # cap + pinned active
    assert ra in eng._path_states                     # pinned, not evicted
    assert eng.request_path_state(ra) is not None
    results = eng.run()                               # ra finishes cleanly
    assert any(r.rid == ra and r.n_generated == 8 for r in results)


def test_engine_backpressure_bounds_active_slots():
    """Prefill admission is gated on free decode slots, so the in-flight
    KV working set never exceeds max_batch_requests; every request still
    completes (FCFS, no starvation)."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64,
                                             max_batch_requests=2))
    rng = np.random.RandomState(27)
    rids = [eng.submit(rng.randint(0, cfg.vocab_size, (6,)), arrival=0.0,
                       max_new_tokens=4) for _ in range(5)]
    results = []
    for _ in range(100):
        results.extend(eng.step(now=0.0))
        assert eng.active() <= 2
        if not eng.has_work():
            break
    assert sorted(r.rid for r in results) == rids
    assert all(r.n_generated == 4 for r in results)


def test_engine_simulate_generates_and_reports_tpot():
    cfg, server = _smoke_server(capacity_factor=16.0)
    rng = np.random.RandomState(25)
    trace = [(rng.randint(0, cfg.vocab_size, (8,)), 0.01 * i)
             for i in range(4)]
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=32))
    results = simulate(eng, trace, max_new_tokens=3)
    assert len(results) == 4
    for r in results:
        assert r.n_generated == 3
        assert r.arrival <= r.ttft <= r.completion
        assert r.tpot is not None and r.tpot >= 0
    assert not eng.has_work()


def _tokens_of(results):
    return {r.rid: (None if r.tokens is None else r.tokens.tolist())
            for r in results}


def test_engine_plan_swap_mid_decode_is_transparent():
    """Controller-triggered plan swaps between micro-batches must not
    change any request's generated tokens: plans move experts across
    devices, they do not change the math, and decode state (KV cache +
    rolling path ids) survives the swap untouched."""
    from repro.sched import AdaptiveScheduler, ControllerConfig

    cfg, ref_server = _smoke_server(capacity_factor=16.0)
    rng = np.random.RandomState(31)
    prompts = [rng.randint(0, cfg.vocab_size, (10,)) for _ in range(3)]

    ref_eng = ServingEngine(ref_server, EngineConfig(max_batch_tokens=64))
    for p in prompts:
        ref_eng.submit(p, arrival=0.0, max_new_tokens=6)
    ref = _tokens_of(ref_eng.run())

    _, server = _smoke_server(capacity_factor=16.0)
    sched = AdaptiveScheduler(server, ControllerConfig(
        interval=1, min_swap_interval=1, min_observations=1,
        hysteresis=0.0, migration_weight=0.0))
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64),
                        scheduler=sched)
    for p in prompts:
        eng.submit(p, arrival=0.0, max_new_tokens=6)
    results = []
    swapped_mid_decode = False
    while eng.has_work():
        before = sched.controller.swaps + sched.controller.bootstraps
        results.extend(eng.step(now=0.0))
        published = sched.controller.swaps + sched.controller.bootstraps
        if eng.active() and published > before:
            swapped_mid_decode = True
    assert swapped_mid_decode            # plans were live while decoding
    assert server._plan_override         # controller owns layers now
    assert _tokens_of(results) == ref    # ... and tokens are identical
    # overridden layers bypass the blocking phase-2 fine-tune entirely
    post_stats = [s for s in list(eng.layer_stats)[-4 * cfg.n_moe_layers:]]
    assert not any(s.finetuned for s in post_stats)


def test_engine_warmup_pretraces_and_leaves_no_trace():
    """Warm-up compiles the (batch-bucket, min_replicas) dispatch grid and
    the prefill/decode paths, restores the PlanCache untouched, and a
    subsequent same-shape serve hits the jit cache instead of compiling."""
    cfg, server = _smoke_server(capacity_factor=16.0)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64,
                                             max_batch_requests=4))
    n = eng.warmup(seqs=(12,), max_new_tokens=3, min_replicas_grid=(1, 2))
    assert n > 0
    # no scheduling trace: cache empty, stats zeroed, no overrides
    assert server.plan_cache._plans == {}
    st = server.plan_cache.stats
    assert (st.hits, st.misses, st.invalidations) == (0, 0, 0)
    assert server._plan_override == {}
    size = server._dispatch._cache_size()
    assert size > 0
    # a second warm-up at the same grid re-traces nothing: the engine's own
    # jit-cache accounting AND the analyzer's jit tracing-cache counter
    # (repro.analysis pass 3) must both stay flat
    from repro.analysis.retrace import no_retrace, supported
    with no_retrace("second engine warmup at an identical grid") as rep:
        eng.warmup(seqs=(12,), max_new_tokens=3, min_replicas_grid=(1, 2))
    assert server._dispatch._cache_size() == size
    if supported():
        assert rep.count == 0


def test_engine_simulate_open_loop_latency():
    cfg, server = _smoke_server()
    rng = np.random.RandomState(3)
    toks = rng.randint(0, cfg.vocab_size, (16,))
    # steady traffic: identical requests -> stable popularity -> plan reuse
    trace = [(toks, 0.01 * i) for i in range(6)]
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=32))
    results = simulate(eng, trace)
    assert len(results) == 6
    assert all(r.latency >= 0 for r in results)
    assert all(r.completion >= r.arrival for r in results)
    # steady traffic + cached plans => some reuse after the first batch
    assert eng.plan_reuse_rate > 0.0


def test_engine_device_failure_mid_decode_keeps_tokens_bitwise():
    """A device failing mid-decode must degrade transparently: the dead
    device's route weights are zeroed (zero-migration re-route), affected
    cached plans are invalidated and replanned under the device mask, and
    — because every replica serves the identical expert math and capacity
    has headroom — every request's generated tokens stay bitwise identical
    to the fault-free run.  Decode slots are never lost."""
    cfg, ref_server = _smoke_server(capacity_factor=16.0)
    rng = np.random.RandomState(47)
    prompts = [rng.randint(0, cfg.vocab_size, (10,)) for _ in range(3)]

    ref_eng = ServingEngine(ref_server, EngineConfig(max_batch_tokens=64))
    for p in prompts:
        ref_eng.submit(p, arrival=0.0, max_new_tokens=6)
    ref = _tokens_of(ref_eng.run())

    _, server = _smoke_server(capacity_factor=16.0)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64))
    for p in prompts:
        eng.submit(p, arrival=0.0, max_new_tokens=6)
    results, failed_mid_decode, post_fail_stats = [], False, []
    while eng.has_work():
        results.extend(eng.step(now=0.0))
        if not server.dead_devices and eng.active():
            server.fail_devices({1})             # die mid-decode
            failed_mid_decode = eng.active() > 0
            n_before = len(eng.layer_stats)
        if server.dead_devices:
            post_fail_stats = list(eng.layer_stats)[n_before:]
    assert failed_mid_decode                     # requests were in flight
    assert server.dead_devices == {1}
    assert _tokens_of(results) == ref            # bitwise-identical output
    # the re-route is real: no realized load lands on the dead device
    assert post_fail_stats
    for s in post_fail_stats:
        assert float(np.asarray(s.device_load)[1]) == 0.0
