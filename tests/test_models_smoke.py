"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + train step on CPU, asserting output shapes and no NaNs (full
configs are exercised via the dry-run only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, list_archs
from repro.models import (decode_step, forward_prefill, forward_train,
                          init_cache, init_params)

B, S = 2, 32


def make_batch(cfg, key):
    if cfg.frontend == "audio_stub":
        return {"frames": jax.random.normal(key, (B, S, 512)),
                "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.frontend == "vision_stub":
        st = S - cfg.n_patches
        return {"tokens": jnp.zeros((B, st), jnp.int32),
                "labels": jnp.zeros((B, st), jnp.int32),
                "patches": jax.random.normal(key, (B, cfg.n_patches,
                                                   cfg.d_model))}
    return {"tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke(arch):
    cfg = REGISTRY[arch].smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)

    out = jax.jit(lambda p, b: forward_train(None, cfg, p, b, lina=False))(
        params, batch)
    assert out.loss.shape == ()
    assert np.isfinite(float(out.loss))
    if cfg.moe.enabled:
        assert float(out.aux_loss) > 0
        assert out.expert_choices is not None

    pre = jax.jit(lambda p, b: forward_prefill(None, cfg, p, b))(params, batch)
    assert pre.logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(pre.logits, np.float32)).all()

    if cfg.causal:
        cache = init_cache(cfg, B, 16, jnp.float32)
        logits, cache2, experts = jax.jit(
            lambda p, c, t: decode_step(None, cfg, p, c, t))(
            params, cache, jnp.zeros((B,), jnp.int32))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(cache2.pos[0]) == 1
        if cfg.moe.enabled and not cfg.layer_pattern:
            assert experts.shape == (cfg.n_moe_layers, B)
            assert (np.asarray(experts) < cfg.moe.n_experts).all()


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x22b", "zamba2-1.2b",
                                  "rwkv6-1.6b"])
def test_decode_matches_prefill(arch):
    """Token-by-token decode must reproduce the prefill logits — validates
    every cache path (KV ring, SSM state, RWKV state, MoE decode)."""
    cfg = REGISTRY[arch].smoke()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)

    pre = forward_prefill(None, cfg, params, {"tokens": toks})
    cache = init_cache(cfg, B, 16, jnp.float32)
    logits = None
    step = jax.jit(lambda p, c, t: decode_step(None, cfg, p, c, t))
    for i in range(8):
        logits, cache, _ = step(params, cache, toks[:, i])
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(pre.logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_hubert_mask_positions_drive_loss():
    cfg = REGISTRY["hubert-xlarge"].smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = make_batch(cfg, jax.random.PRNGKey(2))
    out = forward_train(None, cfg, params, b)
    assert np.isfinite(float(out.loss))


def test_vlm_patch_prefix_changes_logits():
    cfg = REGISTRY["llava-next-34b"].smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b1 = make_batch(cfg, jax.random.PRNGKey(3))
    b2 = dict(b1)
    b2["patches"] = b1["patches"] + 1.0
    l1 = forward_prefill(None, cfg, params, b1).logits
    l2 = forward_prefill(None, cfg, params, b2).logits
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
