"""Popularity estimation (sample paths, Ψ tables): pattern recovery and
accuracy metrics — the mechanism behind paper Fig. 9/19 and Table 5."""
import numpy as np
from _hyp_compat import given, settings, st

from repro.core.popularity import (PathProfile, estimation_accuracy,
                                   exact_buckets, rolling_path_id)


def synth_choices(n_layers, t, e, seed, pattern_strength=1.0):
    """Token stream where layer i+1's expert is a fixed function of layer
    i's (with probability pattern_strength) — the paper's §5.2 pattern.
    Layer-0 choices are Zipf-skewed (inference-style skew, Fig. 6) so the
    per-layer popularity is skewed-and-predictable rather than uniform."""
    rng = np.random.RandomState(1234)       # pattern fixed across batches
    nxt = rng.permutation(e)
    p = 1.0 / (np.arange(e) + 1.0) ** 1.5
    p = p / p.sum()
    rng = np.random.RandomState(seed)
    choices = np.zeros((n_layers, t), np.int64)
    choices[0] = rng.choice(e, size=t, p=p)
    for i in range(1, n_layers):
        follow = rng.rand(t) < pattern_strength
        choices[i] = np.where(follow, nxt[choices[i - 1]],
                              rng.choice(e, size=t, p=p))
    return choices


def test_rolling_hash_exact_for_small_space():
    e, l = 4, 3
    b = exact_buckets(e, l)
    assert b == e ** l
    # two distinct length-l paths map to distinct ids
    p1 = p2 = np.int64(0)
    for x, y in [(1, 1), (2, 2), (3, 0)]:
        p1 = rolling_path_id(p1, np.int64(x), e, l, b)
        p2 = rolling_path_id(p2, np.int64(y), e, l, b)
    assert p1 != p2


def test_profile_learns_deterministic_pattern():
    n_layers, t, e = 8, 2048, 8
    prof = PathProfile(n_layers=n_layers, n_experts=e, path_len=3)
    for s in range(4):
        prof.profile_batch(synth_choices(n_layers, t, e, s, 1.0))
    # with a deterministic pattern, estimation nails the next layer
    test = synth_choices(n_layers, t, e, 99, 1.0)
    path = np.zeros((t,), np.int64)
    hits = 0
    total = 0
    for i in range(n_layers):
        if i >= 3:
            est = prof.estimate_popularity(i, path)
            actual = np.bincount(test[i], minlength=e) / t
            hits += estimation_accuracy(est, actual, k=1)
            total += 1
        path = (path * e + test[i]) % prof.n_buckets
    assert hits / total >= 0.75


@given(strength=st.sampled_from([0.0, 0.5, 1.0]), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_distribution_normalized(strength, seed):
    n_layers, t, e = 6, 256, 4
    prof = PathProfile(n_layers=n_layers, n_experts=e, path_len=2)
    prof.profile_batch(synth_choices(n_layers, t, e, seed, strength))
    dist = prof.distribution(4, np.arange(t) % prof.n_buckets)
    s = dist.sum(-1)
    np.testing.assert_allclose(s, np.ones_like(s), atol=1e-5)
    assert (dist >= 0).all()


def test_stronger_pattern_beats_weaker():
    """Estimation accuracy must increase with pattern strength (Fig. 9)."""
    n_layers, t, e = 8, 2048, 8

    def acc(strength):
        prof = PathProfile(n_layers=n_layers, n_experts=e, path_len=3)
        for s in range(3):
            prof.profile_batch(synth_choices(n_layers, t, e, s, strength))
        test = synth_choices(n_layers, t, e, 77, strength)
        path = np.zeros((t,), np.int64)
        hits = total = 0
        for i in range(n_layers):
            if i >= 3:
                est = prof.estimate_popularity(i, path)
                actual = np.bincount(test[i], minlength=e) / t
                hits += estimation_accuracy(est, actual, k=1)
                total += 1
            path = (path * e + test[i]) % prof.n_buckets
        return hits / total

    assert acc(1.0) >= acc(0.0)


def test_save_load_roundtrip(tmp_path):
    prof = PathProfile(n_layers=4, n_experts=8, path_len=2)
    prof.profile_batch(synth_choices(4, 128, 8, 0))
    p = str(tmp_path / "prof.npz")
    prof.save(p)
    prof2 = PathProfile.load(p)
    np.testing.assert_array_equal(prof.counts, prof2.counts)
    assert prof2.path_len == 2 and prof2.n_buckets == prof.n_buckets
