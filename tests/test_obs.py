"""Unified observability layer (repro.obs): span-tree invariants, the
TTFT = queue + prefill + insert identity on a real engine run (including
the Chrome-trace export round-trip the validator gates in CI), the
disabled fast path (no span allocation, bounded overhead), histogram
quantile accuracy against exact quantiles, the Prometheus text
round-trip, the admission ledger read back through the metrics view, and
the overlap attribution replay against BENCH_schedules.json."""
import json
import os
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.popularity import PathProfile
from repro.obs import (Histogram, MetricsRegistry, NOOP, ObsContext, Tracer,
                       attribute_overlap, check_span_tree, hidden_fraction,
                       parse_prometheus, to_chrome, tree_from_chrome)
from repro.obs.__main__ import check_ledger, check_request_ttft
from repro.obs.__main__ import main as obs_validate
from repro.models import lm as lm_mod
from repro.runtime.engine import EngineConfig, ServingEngine, simulate
from repro.runtime.server import MoEServer, ServerConfig
from repro.sched import get_trace

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --- tracer core ------------------------------------------------------------

class _FakeClock:
    """Deterministic monotonic clock: each read advances by ``tick``."""

    def __init__(self, tick=1.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


def test_span_tree_invariants_catch_violations():
    tr = Tracer(enabled=True)
    ok = tr.begin("step", start=0.0)
    ok.child("a", 0.0, 0.4)
    ok.child("b", 0.4, 0.9)
    ok.end_at(1.0)
    assert check_span_tree(tr.roots) == []

    overlapping = tr.begin("step2", start=0.0)
    overlapping.child("x", 0.0, 0.8)
    overlapping.child("y", 0.2, 0.9)           # phases overlap: sum 1.5 > 1.0
    overlapping.end_at(1.0)
    errs = check_span_tree(tr.roots)
    assert any("sum" in e for e in errs)

    tr.clear()
    escape = tr.begin("step3", start=0.0)
    escape.child("z", 0.0, 2.0)                # child past parent end
    escape.end_at(1.0)
    tr.begin("never_closed", start=0.0)        # left open
    errs = check_span_tree(tr.roots)
    assert any("escapes" in e for e in errs)
    assert any("open span" in e for e in errs)


def test_stack_spans_nest_and_add_lands_under_open_span():
    tr = Tracer(enabled=True, clock=_FakeClock())
    with tr.span("outer", layer=3) as outer:
        with tr.span("inner"):
            pass
        tr.add("manual", outer.start + 0.1, outer.start + 0.2, tag="m")
    assert len(tr.roots) == 1
    assert [c.name for c in tr.roots[0].children] == ["inner", "manual"]
    assert tr.roots[0].attrs == {"layer": 3}
    assert check_span_tree(tr.roots) == []
    # outside any open span, add() becomes a root
    tr.add("rootish", 100.0, 101.0)
    assert tr.roots[-1].name == "rootish"


def test_disabled_tracer_allocates_no_spans():
    tr = Tracer(enabled=False)
    assert tr.span("s") is NOOP
    assert tr.begin("s") is NOOP
    assert tr.add("s", 0.0, 1.0) is NOOP
    with tr.span("s", layer=1) as sp:
        assert sp is NOOP
        assert sp.set(a=1) is NOOP
        assert sp.begin_child("c", 0.0) is NOOP
        assert sp.child("c", 0.0, 1.0).end_at(2.0) is NOOP
    assert tr.roots == [] and tr._stack == []
    # the stopwatch still measures (its dt is functional), but records nothing
    with tr.timed("sw") as sw:
        pass
    assert sw.dt >= 0.0
    assert tr.roots == []


def test_root_cap_counts_drops_instead_of_silently_capping():
    tr = Tracer(enabled=True, max_roots=2)
    for i in range(5):
        tr.add(f"r{i}", float(i), float(i) + 0.5)
    assert len(tr.roots) == 2
    assert tr.dropped_roots == 3


# --- metrics ----------------------------------------------------------------

def test_histogram_quantiles_match_exact_within_bucket_resolution():
    rng = np.random.RandomState(0)
    xs = rng.lognormal(mean=-5.0, sigma=1.2, size=5000)   # ~ms-scale latencies
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    assert h.count == xs.size
    np.testing.assert_allclose(h.sum, xs.sum(), rtol=1e-9)
    for q in (0.50, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        got = h.quantile(q)
        # default buckets are 4/octave: ~19% relative resolution
        assert abs(got - exact) / exact < 0.20, (q, got, exact)
    assert Histogram().quantile(0.5) != Histogram().quantile(0.5)  # NaN


def test_prometheus_round_trip_is_sample_exact():
    reg = MetricsRegistry()
    reg.counter("reqs_total", policy="lina").inc(3)
    reg.counter("reqs_total", policy="uniform").inc()
    reg.gauge("queue_depth").set(2.5)
    h = reg.histogram("lat_s", policy="lina")
    for v in (1e-4, 3e-4, 2e-3, 0.5, 2000.0):              # incl. overflow
        h.observe(v)
    text = reg.to_prometheus()
    assert parse_prometheus(text) == reg.to_samples()
    # the le label is emitted sorted in with the user labels, and the
    # overflow observation lands in the +Inf bucket
    assert 'lat_s_bucket{le="+Inf",policy="lina"} 5' in text
    with pytest.raises(TypeError):
        reg.gauge("reqs_total")                            # type collision


# --- engine runs ------------------------------------------------------------

def _smoke_stack(obs, capacity_factor=16.0, **ecfg_kw):
    import dataclasses
    import jax
    cfg = get_config("gpt2-moe").smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=capacity_factor))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    server = MoEServer(cfg, params, prof,
                       ServerConfig(path_len=2, schedule_policy="lina"),
                       obs=obs)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64,
                                             max_batch_requests=4,
                                             **ecfg_kw))
    return cfg, eng


@pytest.fixture(scope="module")
def traced_drift_run(tmp_path_factory):
    """One drift-workload engine run with tracing enabled, exported."""
    obs = ObsContext.enabled()
    cfg, eng = _smoke_stack(obs)
    trace = get_trace("drift", cfg.vocab_size, n_requests=6, seq=8,
                      rate_hz=50.0, seed=3)
    results = simulate(eng, trace, max_new_tokens=3)
    out = str(tmp_path_factory.mktemp("obs_drift"))
    paths = obs.export(out)
    return obs, eng, results, out, paths


def test_ttft_identity_holds_on_drift_run(traced_drift_run):
    obs, eng, results, _out, _paths = traced_drift_run
    assert len(results) == 6
    spans = obs.tracer.roots
    assert check_span_tree(spans) == []
    errs, n = check_request_ttft(spans, tol=1e-6)
    assert errs == [] and n == 6
    # the span-tree TTFT agrees with the engine's own result objects
    by_rid = {r.rid: r for r in results}
    for root in spans:
        if root.name != "request" or "ttft_s" not in root.attrs:
            continue
        r = by_rid[root.attrs["rid"]]
        assert abs(root.attrs["ttft_s"] - r.ttft_latency) < 1e-9
        assert root.attrs["outcome"] == "done"
    # ... and with the registry histograms the benchmark columns read
    h = obs.metrics.get("engine_ttft_s")
    assert h is not None and h.count == 6


def test_chrome_export_round_trips_the_decomposition(traced_drift_run):
    obs, _eng, _results, out, paths = traced_drift_run
    with open(paths["trace"]) as f:
        chrome = json.load(f)
    assert chrome["traceEvents"], "empty Chrome trace"
    trees = tree_from_chrome(chrome)
    errs, n = check_request_ttft(trees, tol=1e-5)
    assert errs == [] and n == 6
    # the CLI validator (the CI gate) passes on the exported artifact set
    assert obs_validate(["validate", "--trace-dir", out,
                         "--require-requests", "6"]) == 0


def test_engine_step_spans_carry_phase_children(traced_drift_run):
    obs, _eng, _results, _out, _paths = traced_drift_run
    steps = [r for r in obs.tracer.roots if r.name == "engine.step"]
    assert steps
    for st in steps:
        names = {c.name for c in st.children}
        assert names <= {"decode", "prefill", "insert"}
    assert any("decode" in {c.name for c in st.children} for st in steps)


@pytest.fixture(scope="module")
def untraced_run():
    """The same engine path with the default (tracing-off) context."""
    obs = ObsContext.disabled()
    cfg, eng = _smoke_stack(obs)
    rng = np.random.RandomState(5)
    trace = [(rng.randint(0, cfg.vocab_size, (8,)), 0.02 * i)
             for i in range(6)]
    results = simulate(eng, trace, max_new_tokens=3)
    return obs, eng, results


def test_disabled_run_allocates_no_spans_but_keeps_metrics(untraced_run):
    obs, eng, results = untraced_run
    assert len(results) == 6
    assert obs.tracer.roots == []
    assert eng._req_spans == {}
    # the ledgers stay live: metrics are always on
    assert obs.metrics.value("engine_requests_offered_total") == 6
    assert obs.metrics.value("engine_requests_completed_total") == 6
    assert obs.metrics.get("engine_ttft_queue_s").count == 6


def test_disabled_tracing_overhead_within_2pct(untraced_run):
    """The per-call cost of a disabled span, times a generous bound on
    obs calls per engine step, must stay under 2% of a measured step's
    service time — the guard that keeps production serving free to leave
    tracing off-by-default without a perf tax."""
    obs, _eng, _results = untraced_run
    tr = Tracer(enabled=False)
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("s", layer=0):
            pass
    per_call = (time.perf_counter() - t0) / n
    step_h = obs.metrics.get("engine_step_service_s")
    assert step_h is not None and step_h.count > 0
    mean_step = step_h.sum / step_h.count
    calls_per_step = 64          # ~25 in reality (engine 3 + 5/MoE layer)
    assert per_call * calls_per_step < 0.02 * mean_step, \
        (per_call, mean_step)


def test_admission_ledger_closes_through_metrics_view():
    obs = ObsContext.disabled()
    cfg, eng = _smoke_stack(obs, max_queue=1)
    rng = np.random.RandomState(9)
    # a same-instant burst against a depth-1 queue: most submits bounce,
    # and with no retry budget the client records give-ups on the ledger
    trace = [(rng.randint(0, cfg.vocab_size, (8,)), 0.0) for _ in range(8)]
    results = simulate(eng, trace, max_new_tokens=2, retry_backoff_s=0.0)
    assert eng.shed_records                    # some traffic was refused
    samples = parse_prometheus(obs.metrics.to_prometheus())
    assert check_ledger(samples) == []
    offered = samples["engine_requests_offered_total"]
    completed = samples["engine_requests_completed_total"]
    shed = sum(v for k, v in samples.items()
               if k.startswith("engine_requests_shed_total"))
    assert shed == len(eng.shed_records) > 0
    assert offered == completed + shed == len(trace)
    assert completed == len(results)


# --- overlap attribution ----------------------------------------------------

def test_overlap_attribution_matches_bench_json():
    """hidden_fraction recomputed FROM THE TRACE must equal each
    BENCH_schedules.json overlap row's a2a_hidden_frac — and survive a
    Chrome export round-trip (the acceptance identity of the obs layer)."""
    with open(os.path.join(REPO_ROOT, "BENCH_schedules.json")) as f:
        rows = json.load(f)["overlap"]
    assert rows, "BENCH_schedules.json has no overlap rows"
    tr = Tracer(enabled=True)
    roots = attribute_overlap(tr, rows)
    assert len(roots) == len(rows)
    assert check_span_tree(tr.roots) == []
    for root, row in zip(roots, rows):
        # rows store values printed at 0.1us so allow that quantization
        assert abs(hidden_fraction(root) - row["a2a_hidden_frac"]) < 0.01
    trees = tree_from_chrome(to_chrome(tr))
    assert len(trees) == len(rows)
    for tree, row in zip(trees, rows):
        assert abs(hidden_fraction(tree) - row["a2a_hidden_frac"]) < 0.01


def test_attribution_on_a_disabled_tracer_is_empty():
    tr = Tracer(enabled=False)
    rows = [{"variant": "pipelined", "chunks_requested": 2,
             "chunks_chosen": 2, "us_per_call": 150.0, "serial_us": 200.0,
             "a2a_us": 100.0, "a2a_hidden_frac": 0.5}]
    roots = attribute_overlap(tr, rows)
    assert tr.roots == []
    assert all(r is NOOP for r in roots)
    assert hidden_fraction(NOOP) == 0.0
