"""Unit tests for the adaptive resource scheduler (repro.sched): workload
determinism, replica-target properties (monotonicity, budget/floor), plan
construction from explicit counts (incremental placement), telemetry EWMA /
drift behavior, and controller hysteresis bounding churn."""
import dataclasses

import numpy as np
import pytest

from repro.core.placement import (migration_slots, plan_from_replicas,
                                  plan_placement, transfer_balance_cost)
from repro.runtime.server import LayerStats
from repro.sched import (AutoscaleController, ControllerConfig, TelemetryBus,
                         TelemetryConfig, TraceSpec, generate_trace,
                         replica_targets)


# --- workload engine --------------------------------------------------------

def test_trace_seeded_determinism():
    spec = TraceSpec(kind="drifting_zipf", n_requests=12, seq=8, seed=5)
    a = generate_trace(spec, 256)
    b = generate_trace(spec, 256)
    assert len(a) == len(b) == 12
    for (ta, aa), (tb, ab) in zip(a, b):
        np.testing.assert_array_equal(ta, tb)
        assert aa == ab
    c = generate_trace(dataclasses.replace(spec, seed=6), 256)
    assert any((ta != tc).any() for (ta, _), (tc, _) in zip(a, c))


def test_trace_kinds_and_validation():
    with pytest.raises(ValueError, match="unknown workload kind"):
        TraceSpec(kind="tsunami")
    for kind in ("stationary", "drifting_zipf", "flash_crowd", "diurnal"):
        tr = generate_trace(TraceSpec(kind=kind, n_requests=8, seq=4), 128)
        assert len(tr) == 8
        assert all(t.shape == (4,) and (t >= 0).all() and (t < 128).all()
                   for t, _ in tr)
        arr = [at for _, at in tr]
        assert arr == sorted(arr)


def test_flash_crowd_bursts_arrivals():
    """Inside the flash window arrivals are flash_mult denser and tokens
    come from the tiny far pool."""
    spec = TraceSpec(kind="flash_crowd", n_requests=400, seq=4, seed=0,
                     rate_hz=100.0, flash_mult=8.0, flash_pool=2)
    tr = generate_trace(spec, 1024)
    d = spec.duration
    lo, hi = spec.flash_start * d, (spec.flash_start + spec.flash_dur) * d
    inside = [t for t, at in tr if lo <= at < hi]
    # the burst window holds far more than its share of requests
    assert len(inside) > 2 * spec.flash_dur * len(tr)
    # burst tokens all come from a 2-token pool
    assert len({int(x) for t in inside for x in t}) <= 2


def test_drifting_mixture_moves_hot_tokens():
    spec = TraceSpec(kind="drifting_zipf", n_requests=60, seq=32, seed=3,
                     rate_hz=30.0, drift_period=2.0)
    tr = generate_trace(spec, 512)
    third = len(tr) // 3
    early = np.bincount(np.concatenate([t for t, _ in tr[:third]]),
                        minlength=512)
    late = np.bincount(np.concatenate([t for t, _ in tr[-third:]]),
                       minlength=512)
    # the dominant token set rotates with the mixture
    assert early.argmax() != late.argmax()


# --- replica targets --------------------------------------------------------

def test_replica_targets_monotone_in_popularity():
    rng = np.random.RandomState(0)
    for _ in range(20):
        pop = rng.dirichlet(np.ones(16) * 0.5)
        for drift in (0.0, 0.5, 1.0):
            r = replica_targets(pop, 16, drift_rate=drift, budget=48)
            order = np.argsort(-pop)
            sorted_r = r[order]
            assert (np.diff(sorted_r) <= 0).all(), (pop, r)


def test_replica_targets_budget_floor_and_bounds():
    pop = np.array([.6, .2, .1, .05, .03, .01, .005, .005])
    r = replica_targets(pop, 8, budget=24, floor=2)
    assert r.sum() <= 24
    assert (r >= 2).all()
    assert (r <= 8).all()
    # floor clips to what the budget can host
    r1 = replica_targets(pop, 8, budget=8, floor=4)
    assert (r1 == 1).all()
    # hedge: full drift pulls allocations toward uniform
    r_flat = replica_targets(pop, 8, drift_rate=1.0, headroom=5.0, budget=24)
    r_sharp = replica_targets(pop, 8, drift_rate=0.0, budget=24)
    assert r_flat.max() <= r_sharp.max()


# --- plan construction ------------------------------------------------------

def test_plan_from_replicas_honors_counts_and_shapes():
    pop = np.array([.5, .2, .2, .1])
    counts = np.array([4, 2, 2, 1])
    plan = plan_from_replicas(pop, counts, n_devices=8, max_pack=2,
                              rep_width=8)
    np.testing.assert_array_equal(plan.n_replicas, counts)
    assert plan.replica_of.shape == (4, 8)
    assert plan.slot_expert.shape == (8, 2)
    # every replica slot maps back to its expert
    for ex in range(4):
        for s in plan.replica_of[ex][: counts[ex]]:
            d, sub = divmod(int(s), 2)
            assert plan.slot_expert[d, sub] == ex
    # replicas of one expert spread across distinct devices
    devs = [int(s) // 2 for s in plan.replica_of[0][:4]]
    assert len(set(devs)) == 4


def test_plan_from_replicas_budget_shed_and_overflow():
    pop = np.ones(4) / 4
    plan = plan_from_replicas(pop, np.array([8, 8, 8, 8]), n_devices=4,
                              max_pack=2)
    assert plan.n_replicas.sum() == 8          # shed to the slot budget
    with pytest.raises(ValueError):
        plan_from_replicas(np.ones(16) / 16, np.ones(16), n_devices=2,
                           max_pack=2)


def test_plan_from_replicas_incremental_retention():
    """With ``prev`` given, unchanged replica counts keep their devices —
    a swap that only widens one expert moves only the added replicas."""
    pop = np.array([.4, .3, .2, .1])
    r0 = np.array([2, 2, 2, 2])
    p0 = plan_from_replicas(pop, r0, n_devices=8, max_pack=2, rep_width=8)
    r1 = np.array([4, 2, 2, 2])
    p1 = plan_from_replicas(pop, r1, n_devices=8, max_pack=2, rep_width=8,
                            prev=p0)
    assert migration_slots(p0, p1) == 2        # only the two new replicas
    p1_fresh = plan_from_replicas(pop, r1, n_devices=8, max_pack=2,
                                  rep_width=8)
    assert migration_slots(p0, p1_fresh) >= migration_slots(p0, p1)


def test_transfer_balance_cost_and_migration():
    pop = np.array([.7, .1, .1, .1])
    skew = plan_from_replicas(pop, np.array([1, 1, 1, 1]), 4, max_pack=2)
    wide = plan_from_replicas(pop, np.array([4, 1, 1, 1]), 4, max_pack=2)
    assert transfer_balance_cost(wide, pop) < transfer_balance_cost(skew, pop)
    assert migration_slots(skew, skew) == 0
    assert migration_slots(skew, wide) > 0


# --- telemetry --------------------------------------------------------------

def _stat(layer, pop, n_tokens=64, finetuned=False, reused=False):
    pop = np.asarray(pop, np.float64)
    return LayerStats(layer, pop, pop, finetuned, True, reused,
                      device_load=pop[: 4], n_tokens=n_tokens)


def test_bus_ewma_converges_and_drift_stays_low():
    bus = TelemetryBus(TelemetryConfig(alpha=0.5, obs_tokens_ref=64.0))
    pop = np.array([.4, .3, .2, .1])
    for _ in range(30):
        bus.observe_step([_stat(0, pop)], 64)
    np.testing.assert_allclose(bus.popularity(0), pop, atol=1e-3)
    assert bus.drift_rate(0) < 0.05
    lt = bus.layer(0)
    assert lt.steps == 30


def test_bus_drift_rises_on_shift_and_envelope_covers_variance():
    bus = TelemetryBus(TelemetryConfig(alpha=0.5))
    a = np.array([.5, .3, .05, .05, .025, .025, .025, .025])
    b = np.array([.025, .025, .025, .025, .05, .05, .3, .5])
    for _ in range(10):
        bus.observe_step([_stat(0, a)], 64)
    assert bus.drift_rate(0) < 0.05
    for _ in range(6):
        bus.observe_step([_stat(0, b)], 64)
    assert bus.drift_rate(0) > 0.2             # fast EWMA left the slow one
    # alternating traffic: the envelope boosts the volatile hot experts
    # relative to a stable one, beyond what their means alone would give
    bus2 = TelemetryBus(TelemetryConfig(alpha=0.3))
    for i in range(40):
        bus2.observe_step([_stat(0, a if i % 2 else b)], 64)
    mean = bus2.popularity(0)
    env = bus2.popularity_envelope(0, risk=1.0)
    assert env.shape == (8,)
    np.testing.assert_allclose(env.sum(), 1.0, atol=1e-6)
    assert env[0] / env[2] > mean[0] / mean[2]   # volatile over stable


def test_bus_tiny_batches_barely_move_the_ewma():
    bus = TelemetryBus(TelemetryConfig(alpha=0.5, obs_tokens_ref=64.0))
    pop = np.array([.25, .25, .25, .25])
    for _ in range(20):
        bus.observe_step([_stat(0, pop, n_tokens=64)], 64)
    spike = np.array([1.0, 0.0, 0.0, 0.0])
    bus.observe_step([_stat(0, spike, n_tokens=1)], 1)
    assert bus.popularity(0)[0] < 0.27         # one token cannot flip it


def test_bus_cache_rates():
    class Stats:
        hits, misses, invalidations = 8, 2, 1
    bus = TelemetryBus(TelemetryConfig(alpha=1.0))
    bus.observe_cache(Stats())
    assert bus.cache_rates["hit"] == pytest.approx(0.8)
    assert bus.cache_rates["invalidation"] == pytest.approx(0.1)


# --- controller -------------------------------------------------------------

def _feed(bus, layer, pops, n=64):
    for pop in pops:
        bus.observe_step([_stat(layer, pop, n_tokens=n)], n)


def test_controller_bootstraps_then_holds_under_hysteresis():
    rng = np.random.RandomState(1)
    base = np.array([.4, .3, .2, .1])
    ctl = AutoscaleController(4, max_pack=2, cfg=ControllerConfig(
        interval=1, min_observations=1, hysteresis=0.2, max_moves=0))
    bus = TelemetryBus(TelemetryConfig(alpha=0.3))
    swapped = []
    for i in range(1, 41):
        noisy = base + rng.uniform(-0.02, 0.02, 4)
        _feed(bus, 0, [noisy / noisy.sum()])
        swapped.append(ctl.step(bus, i) is not None)
    assert swapped[0]                          # bootstrap fires immediately
    assert ctl.bootstraps == 1
    assert ctl.swaps <= 2                      # hysteresis holds the plan


def test_controller_hysteresis_bounds_churn():
    """Same noisy-but-stationary traffic: zero hysteresis churns far more
    than the default gate; both see identical observations."""
    def churn(hyst):
        rng = np.random.RandomState(2)
        ctl = AutoscaleController(4, max_pack=2, cfg=ControllerConfig(
            interval=1, min_observations=1, hysteresis=hyst,
            migration_weight=0.0, max_moves=0))
        bus = TelemetryBus(TelemetryConfig(alpha=0.9))
        for i in range(1, 61):
            pop = rng.dirichlet([4, 3, 2, 1])
            _feed(bus, 0, [pop])
            ctl.step(bus, i)
        return ctl.swaps
    assert churn(0.0) > 2 * churn(0.3)
    assert churn(0.6) <= 2


def test_controller_tracks_popularity_shift():
    ctl = AutoscaleController(8, max_pack=2, cfg=ControllerConfig(
        interval=1, min_observations=1, hysteresis=0.05, max_moves=0,
        migration_weight=0.0))
    bus = TelemetryBus(TelemetryConfig(alpha=0.5))
    a = np.array([.65, .05, .05, .05, .05, .05, .05, .05])
    _feed(bus, 0, [a] * 6)
    ctl.step(bus, 1)
    assert ctl.plans[0].n_replicas[0] == ctl.plans[0].n_replicas.max()
    b = a[::-1].copy()
    for i in range(2, 12):
        _feed(bus, 0, [b])
        ctl.step(bus, i)
    assert ctl.plans[0].n_replicas[7] == ctl.plans[0].n_replicas.max()
    assert ctl.swaps >= 1 and ctl.migrated_slots > 0


def test_controller_migration_throttle():
    """max_moves bounds how many replicas a single control step adds."""
    ctl = AutoscaleController(8, max_pack=2, cfg=ControllerConfig(
        interval=1, min_observations=1, hysteresis=0.0,
        migration_weight=0.0, max_moves=2))
    bus = TelemetryBus(TelemetryConfig(alpha=1.0))
    flat = np.ones(8) / 8
    _feed(bus, 0, [flat])
    ctl.step(bus, 1)
    base = ctl.plans[0].n_replicas.copy()
    hot = np.array([.9] + [.1 / 7] * 7)
    _feed(bus, 0, [hot])
    ctl.step(bus, 2)
    after = ctl.plans[0].n_replicas
    assert int(after[0]) - int(base[0]) <= 2
    assert ctl.pop_migration() <= 4            # adds + matching sheds
    assert ctl.pop_migration() == 0            # popped


def test_controller_seeded_trace_determinism_end_to_end():
    """Identical seeded traces through identical controller configs yield
    identical swap sequences and final plans (pure numpy, no wall clock)."""
    def run():
        spec = TraceSpec(kind="drifting_zipf", n_requests=30, seq=16, seed=9)
        tr = generate_trace(spec, 64)
        ctl = AutoscaleController(4, max_pack=2, cfg=ControllerConfig(
            interval=2, min_observations=1))
        bus = TelemetryBus(TelemetryConfig(alpha=0.4))
        events = []
        for i, (tokens, _) in enumerate(tr, 1):
            pop = np.bincount(tokens % 4, minlength=4).astype(np.float64)
            _feed(bus, 0, [pop / pop.sum()], n=len(tokens))
            if ctl.step(bus, i):
                events.append((i, tuple(ctl.plans[0].n_replicas.tolist())))
        return events
    assert run() == run()
