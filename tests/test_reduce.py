"""Unit tests for the gradient-reduction subsystem (optim/reduce.py):
config validation, micro-op sizing, single-device schedule identity, the
backward-a2a ordering token, and int8 error-feedback behavior.

Multi-device schedule-vs-baseline equivalence lives in
tests/test_distributed.py (subprocess with forced host devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.moe import MoEParams
from repro.optim import reduce as R
from repro.optim.compression import compress_int8_ef, init_int8_state


def tiny_tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7.0,
            "b": jnp.ones((5,), jnp.float32) * 0.3}


# ---------------------------------------------------------------------------
# config / sizing
# ---------------------------------------------------------------------------

def test_reduce_config_validates():
    with pytest.raises(ValueError, match="unknown schedule"):
        R.ReduceConfig(schedule="fastest")
    with pytest.raises(ValueError, match="unknown compression"):
        R.ReduceConfig(compression="fp4")
    c = R.ReduceConfig("priority+partition+pipeline")
    assert c.ordered and c.partitioned
    assert not R.ReduceConfig("baseline").ordered
    assert not R.ReduceConfig("priority").partitioned


def test_n_chunks_for_bytes():
    g = {"a": jnp.zeros((1000,), jnp.float32)}       # 4000 bytes
    assert R.n_chunks_for_bytes(g, 1000) == 4
    assert R.n_chunks_for_bytes(g, 4000) == 1
    assert R.n_chunks_for_bytes(g, 1e12) == 1        # never zero chunks
    assert R.n_chunks_for_bytes(g, 999) == 5         # ceil


# ---------------------------------------------------------------------------
# single-device identity (collectives over a size-1 dp axis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", R.SCHEDULES)
def test_schedules_identity_on_default_mesh(schedule):
    g = tiny_tree()
    cfg = R.ReduceConfig(schedule, partition_bytes=16)
    red, state = R.reduce_gradients(None, g, cfg,
                                    after=jnp.zeros((), jnp.float32))
    assert state is None
    for k in g:
        np.testing.assert_allclose(np.asarray(red[k]), np.asarray(g[k]),
                                   atol=1e-6)


def test_bf16_compression_roundtrip_close():
    g = tiny_tree()
    cfg = R.ReduceConfig("priority+partition", partition_bytes=16,
                         compression="bf16")
    red, _ = R.reduce_gradients(None, g, cfg)
    for k in g:
        np.testing.assert_allclose(np.asarray(red[k]), np.asarray(g[k]),
                                   rtol=1e-2, atol=1e-2)
        assert red[k].dtype == g[k].dtype          # decompressed back


def test_int8_ef_requires_state():
    cfg = R.ReduceConfig("priority", compression="int8_ef")
    with pytest.raises(ValueError, match="ReduceState"):
        R.reduce_gradients(None, tiny_tree(), cfg)


def test_int8_ef_state_threads_through_reduce():
    g = tiny_tree()
    cfg = R.ReduceConfig("priority+partition", partition_bytes=16,
                         compression="int8_ef")
    state = R.init_reduce_state(g, cfg)
    red, state2 = R.reduce_gradients(None, g, cfg, state=state)
    assert jax.tree_util.tree_structure(state) == \
        jax.tree_util.tree_structure(state2)
    # residual became nonzero (quantization error was captured, not lost)
    res_norm = sum(float(jnp.abs(r).sum())
                   for r in jax.tree.leaves(state2.int8.residual))
    assert res_norm > 0
    for k in g:
        np.testing.assert_allclose(np.asarray(red[k]), np.asarray(g[k]),
                                   rtol=0.02, atol=0.02)


# ---------------------------------------------------------------------------
# error feedback: quantization error must not accumulate across steps
# ---------------------------------------------------------------------------

def test_int8_error_feedback_shrinks_error_across_steps():
    """With EF the *cumulative* applied gradient tracks the true cumulative
    gradient to within one quantization step (the residual), so the time-
    averaged error shrinks ~1/t; without EF the per-step bias adds up."""
    g = {"w": jnp.linspace(0.011, 0.989, 64).reshape(8, 8)}
    steps = 12

    ef_state = init_int8_state(g)
    cum_ef = jnp.zeros_like(g["w"])
    cum_raw = jnp.zeros_like(g["w"])
    avg_err_ef = []
    for t in range(1, steps + 1):
        (q, s), ef_state = compress_int8_ef(g, ef_state)
        cum_ef = cum_ef + q["w"].astype(jnp.float32) * s["w"]
        avg_err_ef.append(float(jnp.abs(cum_ef / t - g["w"]).max()))
        # no-EF reference: quantize fresh every step
        (q0, s0), _ = compress_int8_ef(g, init_int8_state(g))
        cum_raw = cum_raw + q0["w"].astype(jnp.float32) * s0["w"]

    err_ef = float(jnp.abs(cum_ef - steps * g["w"]).max())
    err_raw = float(jnp.abs(cum_raw - steps * g["w"]).max())
    # EF cumulative error is bounded by one step's residual; without EF the
    # constant bias grows linearly in t
    assert err_ef < err_raw
    # and the time-averaged EF error shrinks as steps accumulate
    assert avg_err_ef[-1] < avg_err_ef[0]


# ---------------------------------------------------------------------------
# the ordering token
# ---------------------------------------------------------------------------

def test_backward_a2a_token_none_for_dense_tree():
    assert R.backward_a2a_token(tiny_tree()) is None


def test_backward_a2a_token_from_moe_leaves_and_marker():
    moe = MoEParams(router=jnp.ones((4, 2)), wi=jnp.ones((2, 4, 8)),
                    wu=None, wo=jnp.ones((2, 8, 4)))
    tree = {"dense": jnp.ones((3,)), "moe": moe}
    tok = R.backward_a2a_token(tree)
    assert tok is not None and float(tok) == 0.0
    tok2 = R.backward_a2a_token(tiny_tree(),
                                fwd_marker=jnp.zeros((), jnp.float32))
    assert tok2 is not None and float(tok2) == 0.0
