"""Overlap-pipeline tests: chunk-count resolution edge cases, the
double-buffered ``pipelined_expert_ffn`` vs the serial baseline across
chunk counts (n > C, non-divisors, single-chunk fallback), end-to-end
numerical equivalence of the pipelined+grouped and shortcut variants
against the baseline model (loss / grads / params, both compute
backends) on a forced 8-device mesh, and the pass-2 static check that
the (value, token) pair survives a chunked caller loop.
"""
import textwrap

import pytest

from tests.test_distributed import run_snippet


# --------------------------------------------------- chunk resolution --

def test_resolve_chunk_count():
    from repro.core.microop import resolve_chunk_count
    assert resolve_chunk_count(12, 4) == 4       # exact divisor
    assert resolve_chunk_count(12, 5) == 4       # non-divisor -> largest ≤
    assert resolve_chunk_count(12, 100) == 12    # n > C caps at C
    assert resolve_chunk_count(7, 3) == 1        # prime C: only 1 divides
    assert resolve_chunk_count(8, 8) == 8
    assert resolve_chunk_count(1, 4) == 1
    assert resolve_chunk_count(20, 0) == 1       # degenerate request


def test_chunked_a2a_surfaces_chosen_count():
    """len() of the returned micro-op list IS the chosen chunk count —
    callers can always report requested vs chosen (no silent caps)."""
    out = run_snippet("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import mesh_context
        from repro.core.microop import chunked_all_to_all, resolve_chunk_count
        mesh = jax.make_mesh((8,), ("model",))
        buf = jax.random.normal(jax.random.PRNGKey(0), (8, 12, 4))

        for req in (1, 4, 5, 100):
            def body(b):
                outs = chunked_all_to_all(b, "model", req)
                assert len(outs) == resolve_chunk_count(12, req), (req,
                                                                   len(outs))
                return jnp.concatenate(outs, axis=1)
            with mesh_context(mesh):
                jax.jit(shard_map(body, mesh=mesh,
                                  in_specs=(P(None, None, None),),
                                  out_specs=P(None, None, None),
                                  check_rep=False))(buf)
        print("OK")
    """)
    assert "OK" in out


# ------------------------------------------- pipeline vs serial baseline --

def test_pipelined_ffn_equals_serial_across_chunk_counts():
    """The double-buffered pipeline is numerically exact vs the serial
    (pipeline=False) path for dividing, non-dividing, oversized (n > C)
    and single-chunk counts; pipeline=False matches n_chunks=1."""
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import mesh_context
        from repro.core.microop import pipelined_expert_ffn
        mesh = jax.make_mesh((8,), ("model",))
        E, C, D = 8, 12, 4
        buf = jax.random.normal(jax.random.PRNGKey(0), (E, C, D))
        w = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * 0.3

        def run(n_chunks, pipeline=True):
            def body(b):
                y, tok = pipelined_expert_ffn(
                    b, lambda r: jnp.tanh(r @ w), "model", n_chunks, E,
                    pipeline=pipeline)
                return y + tok   # token is a zero scalar; keeps it live
            with mesh_context(mesh):
                return np.asarray(jax.jit(shard_map(
                    body, mesh=mesh, in_specs=(P(None, None, None),),
                    out_specs=P(None, None, None), check_rep=False))(buf))

        ref = run(4, pipeline=False)            # serial baseline
        assert np.array_equal(run(1), ref)      # single-chunk fallback
        for n in (2, 4, 5, 12, 100):            # incl. non-divisor, n > C
            got = run(n)
            assert np.allclose(got, ref, atol=1e-6), (n,
                np.abs(got - ref).max())
        print("OK")
    """)
    assert "OK" in out


# --------------------------------------- end-to-end variant equivalence --

_VARIANT_EQUIV = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import mesh_context
    from repro.models import lm as lm_mod

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = get_config("gpt2-moe").smoke()
    base = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe,
                                      compute_backend="%(backend)s"))
    dc = DataConfig(vocab_size=base.vocab_size, seq_len=32, global_batch=8)
    batch = {k: jnp.asarray(v)
             for k, v in SyntheticLM(dc).batch(0).items()}

    def loss_and_grads(cfg, params):
        def f(p):
            return lm_mod.forward_train(mesh, cfg, p, batch, lina=True).loss
        with mesh_context(mesh):
            loss, grads = jax.jit(jax.value_and_grad(f))(params)
        return float(loss), grads

    def maxdiff(a, b):
        return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                       - np.asarray(y, np.float32))))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    # --- pipelined (+grouped under pallas) vs the serial baseline:
    # identical params, chunk pipeline on/off must not change the math.
    params = lm_mod.init_params(base, jax.random.PRNGKey(0))
    serial = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, pipeline_ffn=False))
    piped = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, pipeline_ffn=True,
                                      n_microops=4))
    l0, g0 = loss_and_grads(serial, params)
    l1, g1 = loss_and_grads(piped, params)
    assert abs(l0 - l1) < 1e-5, (l0, l1)
    d = maxdiff(g0, g1)
    assert d < 1e-5, d

    # --- shortcut vs shared_expert: same dense branch, fused under the
    # a2a shadow vs added outside — identical params, loss, and grads.
    sh = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, shared_expert=True))
    sc = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, shortcut=True,
                                      pipeline_ffn=True, n_microops=4))
    p_sh = lm_mod.init_params(sh, jax.random.PRNGKey(0))
    p_sc = lm_mod.init_params(sc, jax.random.PRNGKey(0))
    assert maxdiff(p_sh, p_sc) == 0.0           # same init incl. shortcut
    l2, g2 = loss_and_grads(sh, p_sh)
    l3, g3 = loss_and_grads(sc, p_sc)
    assert abs(l2 - l3) < 1e-5, (l2, l3)
    d = maxdiff(g2, g3)
    assert d < 1e-5, d
    print("OK")
"""


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_variants_match_baseline_on_mesh(backend):
    out = run_snippet(_VARIANT_EQUIV % {"backend": backend}, timeout=900)
    assert "OK" in out


# ---------------------------------------------------- pass-2 chunk loop --

_SYN_CHUNK_LOOP = textwrap.dedent('''
    """Synthetic chunked callers for the pass-2 ordering-token check."""

    def pipelined_expert_ffn(x):
        return x, object()

    def loop_keeps_token(xs):
        outs, tok = [], None
        for x in xs:
            y, tok = pipelined_expert_ffn(x)
            outs.append(y)
        return outs, tok

    def loop_drops_token(xs):
        outs = []
        for x in xs:
            y, _ = pipelined_expert_ffn(x)
            outs.append(y)
        return outs
''')


def test_chunk_loop_keeps_ordering_token_pass2(tmp_path):
    """The (value, token) contract survives a chunked caller loop: a loop
    body that discards the a2a completion token is flagged, one that
    threads it through is clean — and the real tree stays clean."""
    from repro.analysis.collectives import analyze_collectives
    (tmp_path / "mod.py").write_text(_SYN_CHUNK_LOOP)
    fs = analyze_collectives(str(tmp_path), rel_prefix="syn",
                             producers={"pipelined_expert_ffn": 1})
    drops = [f.qualname for f in fs
             if f.category == "dropped-ordering-token"]
    assert drops == ["loop_drops_token"]

    import os
    from tests.test_distributed import REPO
    root = os.path.join(REPO, "src", "repro")
    real = [f for f in analyze_collectives(root)
            if f.category == "dropped-ordering-token"]
    assert real == [], [f.key for f in real]
