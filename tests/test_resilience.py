"""Fault-injection harness + graceful degradation (repro.resilience).

Covers the PR-9 contract end to end: seeded fault schedules are
deterministic (same seed -> bitwise-identical chaos replay), admission
control accounts every offered request (completed / shed / rejected —
never silently dropped), corrupted telemetry is rejected by the bus,
injected planner crashes fall back instead of failing the batch, the
trainer's non-finite guard skips and rolls back, and a corrupted
checkpoint falls back to the newest verified step."""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.popularity import PathProfile
from repro.models import lm as lm_mod
from repro.resilience import (FAULT_KINDS, Fault, FaultInjector,
                              FaultSchedule, chaos_schedule, overload_burst,
                              single_device_failure)
from repro.runtime.engine import (EngineConfig, ServingEngine, simulate,
                                  summarize_results)
from repro.runtime.server import MoEServer, ServerConfig
from repro.sched.telemetry import TelemetryBus


# --- schedules --------------------------------------------------------------

def test_chaos_schedule_is_deterministic():
    a = chaos_schedule(seed=11, n_steps=50, n_devices=8, n_layers=4)
    b = chaos_schedule(seed=11, n_steps=50, n_devices=8, n_layers=4)
    assert a == b and a.faults == b.faults
    c = chaos_schedule(seed=12, n_steps=50, n_devices=8, n_layers=4)
    assert a != c
    assert all(f.kind in FAULT_KINDS for f in a.faults)


def test_fault_activity_windows():
    f = Fault("straggler", step=5, duration=3, device=2)
    sched = FaultSchedule([f])
    assert not f.active_at(4)
    assert f.active_at(5) and f.active_at(7)
    assert not f.active_at(8)
    assert sched.starting(5) == [f]
    assert sched.ending(8) == [f]           # last active step was 7
    assert sched.active(6, "straggler") == [f]
    assert sched.active(6, "telemetry") == []
    # permanent faults never end
    perm = single_device_failure(3, device=1).faults[0]
    assert perm.active_at(10 ** 6) and perm.duration < 0


# --- end-to-end chaos determinism -------------------------------------------

def _smoke_server():
    cfg = get_config("gpt2-moe").smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    return cfg, MoEServer(cfg, params, prof,
                          ServerConfig(path_len=2, schedule_policy="lina"))


def _chaos_run(cfg, server, schedule):
    inj = FaultInjector(schedule, resilience=True, rng_seed=5,
                        vocab_size=cfg.vocab_size, burst_seq_len=8)
    eng = ServingEngine(server, EngineConfig(max_batch_tokens=64,
                                             max_queue=4, deadline_s=0.5),
                        fault_injector=inj)
    rng = np.random.RandomState(9)
    trace = [(rng.randint(0, cfg.vocab_size, (10,)), 0.02 * i)
             for i in range(6)]
    results = simulate(eng, trace, time_scale=0.0, max_new_tokens=4,
                       retry_backoff_s=0.01)
    return eng, inj, results


def test_seeded_fault_schedule_replays_bitwise():
    """The same seeded schedule against the same engine must reproduce the
    run exactly: tokens, shed ledger, fired events, penalty log."""
    schedule = FaultSchedule([
        Fault("device_failure", 2, duration=-1, device=1),
        Fault("overload", 3, n_requests=8),
        Fault("telemetry", 4, duration=2),
        Fault("planner_crash", 5, duration=1),
    ])
    runs = []
    for _ in range(2):
        cfg, server = _smoke_server()
        runs.append(_chaos_run(cfg, server, schedule))
    (eng_a, inj_a, res_a), (eng_b, inj_b, res_b) = runs
    toks_a = {r.rid: (None if r.tokens is None else r.tokens.tolist())
              for r in res_a}
    toks_b = {r.rid: (None if r.tokens is None else r.tokens.tolist())
              for r in res_b}
    assert toks_a == toks_b
    assert eng_a.shed_records == eng_b.shed_records
    assert inj_a.report() == inj_b.report()
    assert inj_a.penalty_log == inj_b.penalty_log
    # the schedule actually fired everything it promised
    assert inj_a.events == {"device_failure": 1, "overload": 1,
                            "telemetry": 1, "planner_crash": 1}
    assert eng_a.server.dead_devices == {1}


def test_admission_control_accounts_every_request():
    """Offered == completed + shed, with explicit reject/deadline records —
    the chaos suite's zero-silent-drop invariant at the engine level."""
    schedule = overload_burst(2, n_requests=12)
    cfg, server = _smoke_server()
    eng, inj, results = _chaos_run(cfg, server, schedule)
    m = summarize_results(results, engine=eng)
    offered = 6 + inj.injected
    shed = m["shed_deadline"] + m["shed_rejected"]
    assert inj.injected == 12
    assert inj.injected_rejected > 0          # the burst overflowed the cap
    assert offered == len(results) + shed     # nothing silently dropped
    assert m["submitted"] == len(results) + m["shed_deadline"]
    # rejected records carry rid -1 (no id was consumed)
    assert all(s.rid == -1 for s in eng.shed_records
               if s.reason == "rejected")


# --- always-on rungs ---------------------------------------------------------

def test_telemetry_bus_rejects_corrupted_stats():
    from repro.runtime.server import LayerStats

    def stat(pop):
        return LayerStats(layer=0, est_pop=pop, actual_pop=pop,
                          finetuned=False, est_accurate=True,
                          plan_reused=False,
                          device_load=np.ones(4) / 4, n_tokens=8)

    bus = TelemetryBus()
    bus.observe_step([stat(np.array([.4, .3, .2, .1]))], n_tokens=8)
    bus.observe_step([stat(np.array([np.nan, .3, .2, .1]))], n_tokens=8)
    bus.observe_step([stat(np.array([-.5, .3, .2, .1]))], n_tokens=8)
    assert bus.errors == {"telemetry_rejected": 2}
    assert bus.snapshot()["errors"] == {"telemetry_rejected": 2}
    # the poisoned steps never reached the estimate
    est = bus.popularity(0)
    assert est is not None and np.isfinite(np.asarray(est)).all()


def test_planner_crash_falls_back_and_keeps_serving():
    cfg, server = _smoke_server()

    def hook(what, layer):
        raise RuntimeError("injected planner crash")

    server.fault_hook = hook
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 16))
    logits, stats = server.serve(toks)
    assert np.isfinite(np.asarray(logits)).all()
    assert len(stats) == cfg.n_moe_layers     # every layer still served
    assert server.degrade_stats["planner_errors"] > 0


# --- trainer non-finite guard ------------------------------------------------

def test_trainer_skips_nan_steps_and_rolls_back(tmp_path):
    from repro.data import DataConfig
    from repro.optim.adamw import AdamWConfig
    from repro.runtime import Trainer, TrainerConfig

    cfg = get_config("gpt2-moe").smoke()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                      seed=0)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    tr = Trainer(cfg, dcfg, ocfg, TrainerConfig(
        steps=10, ckpt_dir=str(tmp_path), ckpt_every=2, pack_warmup=3,
        max_bad_steps=2, nan_at_steps=(5, 6)))
    state = tr.run()
    # both injected steps were skipped, never committed
    assert tr.skipped_steps == [5, 6]
    skipped = [m for m in tr.metrics_log if m.get("skipped")]
    assert [m["step"] for m in skipped] == [5, 6]
    # two consecutive bad steps hit max_bad_steps -> one rollback
    assert tr.rollbacks == 1
    # training continued to completion with finite, committed state
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(state))
    good = [m for m in tr.metrics_log if not m.get("skipped")]
    assert good[-1]["step"] == 9


# --- checkpoint corruption fallback ------------------------------------------

def test_restore_latest_skips_corrupted_checkpoints(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint.manager import CorruptCheckpointError

    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": np.arange(8, dtype=np.float32)}
    for step in (1, 2, 3):
        mgr.save(step, {"w": state["w"] * step})
    # corrupt the newest checkpoint's arrays in place
    npz = os.path.join(str(tmp_path), "step_00000003", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(0)
        f.write(b"\x00" * 64)
    with pytest.raises(CorruptCheckpointError):
        mgr.restore(3, state)
    step, restored = mgr.restore_latest(state)
    assert step == 2 and mgr.corrupt_steps == [3]
    np.testing.assert_array_equal(restored["w"], state["w"] * 2)
    # checksum mismatch (not just unreadable file) is also caught: flip a
    # byte inside the manifest's recorded crc -> load must not trust it
    man = os.path.join(str(tmp_path), "step_00000002", "manifest.json")
    with open(man) as f:
        j = json.load(f)
    j[0]["crc32"] ^= 0xFF
    with open(man, "w") as f:
        json.dump(j, f)
    with pytest.raises(CorruptCheckpointError):
        mgr.restore(2, state, verify=True)
    step, restored = mgr.restore_latest(state)
    assert step == 1
    np.testing.assert_array_equal(restored["w"], state["w"])
