"""Trainer integration: loss decreases, checkpoint/restart is bitwise,
failure injection recovers, straggler events are recorded."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def make_trainer(tmp_path, steps=10, fail_at=None, lina=True, seed=0,
                 arch="gpt2-moe", microbatches=1, schedule=None,
                 grad_compression=None):
    cfg = get_config(arch).smoke()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                      seed=seed)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    tcfg = TrainerConfig(steps=steps, ckpt_dir=str(tmp_path), ckpt_every=5,
                         lina=lina, fail_at_step=fail_at, seed=seed,
                         microbatches=microbatches, pack_warmup=3,
                         schedule=schedule, grad_compression=grad_compression)
    return Trainer(cfg, dcfg, ocfg, tcfg)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state)]


def test_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path, steps=15)
    tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert losses[-1] < losses[0] - 0.3


def test_checkpoint_restart_bitwise(tmp_path):
    straight = make_trainer(tmp_path / "a", steps=10)
    s_state = straight.run()

    interrupted = make_trainer(tmp_path / "b", steps=10, fail_at=7)
    with pytest.raises(RuntimeError, match="injected failure"):
        interrupted.run()
    resumed = make_trainer(tmp_path / "b", steps=10)   # restart from ckpt@5
    r_state = resumed.run()

    for a, b in zip(_leaves(s_state), _leaves(r_state)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_restart_bitwise_schedule_microbatch(tmp_path):
    """Bitwise resume must also hold off the default path: gradient
    accumulation (microbatches=2) under the pipelined reduction schedule
    with stateful int8-EF compression (whose residual rides in the
    checkpoint)."""
    kw = dict(steps=10, microbatches=2,
              schedule="priority+partition+pipeline",
              grad_compression="int8_ef")
    straight = make_trainer(tmp_path / "a", **kw)
    s_state = straight.run()
    assert "reduce_state" in s_state

    interrupted = make_trainer(tmp_path / "b", fail_at=7, **kw)
    with pytest.raises(RuntimeError, match="injected failure"):
        interrupted.run()
    resumed = make_trainer(tmp_path / "b", **kw)       # restart from ckpt@5
    r_state = resumed.run()

    for a, b in zip(_leaves(s_state), _leaves(r_state)):
        np.testing.assert_array_equal(a, b)


def test_schedule_logged_per_step(tmp_path):
    tr = make_trainer(tmp_path, steps=3, schedule="priority+partition")
    tr.run()
    assert all(m["schedule"] == "priority+partition" for m in tr.metrics_log)


def test_lina_matches_baseline_numerics(tmp_path):
    """Micro-op scheduling is a schedule change, not a math change: training
    with lina=True and lina=False must produce identical losses (the paper
    §7.1 notes model accuracy is unaffected)."""
    a = make_trainer(tmp_path / "l1", steps=5, lina=True)
    b = make_trainer(tmp_path / "l0", steps=5, lina=False)
    a.run(); b.run()
    la = [m["loss"] for m in a.metrics_log]
    lb = [m["loss"] for m in b.metrics_log]
    np.testing.assert_allclose(la, lb, rtol=1e-5)


def test_microbatch_accumulation_consistent(tmp_path):
    """Gradient accumulation tracks the full-batch run closely.  NOT exact:
    MoE capacity is per-microbatch (half the tokens -> half the capacity),
    so drop boundaries differ slightly — true of DeepSpeed/Tutel too."""
    a = make_trainer(tmp_path / "m1", steps=4, microbatches=1)
    b = make_trainer(tmp_path / "m2", steps=4, microbatches=2)
    a.run(); b.run()
    la = [m["loss"] for m in a.metrics_log]
    lb = [m["loss"] for m in b.metrics_log]
    np.testing.assert_allclose(la, lb, rtol=1e-2, atol=5e-2)


def test_packing_controller_runs(tmp_path):
    tr = make_trainer(tmp_path, steps=5)
    tr.run()
    assert tr.packing_decision is not None
    assert tr.packing_decision.experts_per_device >= 1


def test_packing_uses_mesh_ep_size(tmp_path):
    """With a mesh, the packing controller derives the EP group from
    launch.mesh.ep_size(mesh), not from n_experts (only the mesh-less
    fallback keeps the paper's one-expert-per-device assumption)."""
    from repro.core.packing import choose_packing
    from repro.launch.mesh import ep_size, make_mesh

    cfg = get_config("gpt2-moe").smoke()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=5)
    tcfg = TrainerConfig(steps=5, ckpt_dir=str(tmp_path), ckpt_every=5,
                         pack_warmup=3)
    mesh = make_mesh((1, 1), ("data", "model"))     # ep=1 != n_experts
    tr = Trainer(cfg, dcfg, ocfg, tcfg, mesh=mesh)
    tr.run()
    ep = ep_size(mesh)
    assert ep != cfg.moe.n_experts                  # the fix is observable
    tokens = max(dcfg.global_batch * dcfg.seq_len
                 // max(ep, 1) // max(cfg.moe.n_microops, 1), 1)
    expected = choose_packing(
        tokens, cfg.d_model, cfg.moe.d_ff or cfg.d_ff, cfg.moe.n_experts,
        ep, ffn_mult=3 if cfg.ffn_type == "swiglu" else 2)
    assert tr.packing_decision == expected


def test_straggler_watchdog_structure(tmp_path):
    tr = make_trainer(tmp_path, steps=8)
    tr.run()
    assert isinstance(tr.straggler_events, list)
    for ev in tr.straggler_events:
        assert ev["dt"] > tr.cfg.straggler_factor * ev["median"]
