"""Hypothesis compatibility shim for offline environments.

Uses the real ``hypothesis`` package when it is importable.  Otherwise it
degrades ``@given`` to a deterministic seeded-sample sweep: each strategy is
drawn ``max_examples`` times from a PRNG seeded by the test name, so the
property-test invariants still execute (and fail reproducibly) without the
dependency.  Only the strategy combinators this repo uses are shimmed.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples",
                            getattr(fn, "_hyp_max_examples",
                                    _DEFAULT_EXAMPLES))
                for i in range(n):
                    # string seeding hashes via sha512: stable across
                    # processes, unlike hash() under PYTHONHASHSEED
                    rng = random.Random(f"{fn.__module__}.{fn.__name__}:{i}")
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i} for {fn.__name__}: "
                            f"{drawn}") from e
            # hide the original signature: pytest must not mistake the
            # strategy-drawn params for fixtures
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper
        return deco
