"""Gating + dispatch invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, settings, st

from repro.core import dispatch as D
from repro.core.gating import capacity, top_k_gating


def _gate(t=64, e=8, k=2, cf=2.0, seed=0):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    cap = capacity(t, e, k, cf)
    return logits, top_k_gating(logits, k, cap), cap


def test_gating_shapes_and_ranges():
    logits, g, cap = _gate()
    t, e = logits.shape
    assert g.expert_idx.shape == (t, 2) and g.expert_idx.min() >= 0
    assert int(g.expert_idx.max()) < e
    assert g.gate_weights.shape == (t, 2)
    assert float(g.aux_loss) > 0
    # kept tokens' weights sum to ~1; fully-dropped tokens sum to 0
    ws = np.asarray(g.gate_weights.sum(-1))
    kept = ~np.asarray(g.dropped).all(-1)
    assert np.all((ws[kept] > 0.4) & (ws[kept] <= 1.0 + 1e-6))


def test_gating_positions_unique_per_expert():
    """No two tokens may claim the same (expert, position) slot."""
    _, g, cap = _gate(t=128, e=4, k=2, cf=4.0)
    idx = np.asarray(g.expert_idx).reshape(-1)
    pos = np.asarray(g.position).reshape(-1)
    dropped = np.asarray(g.dropped).reshape(-1)
    slots = [(e, p) for e, p, d in zip(idx, pos, dropped) if not d]
    assert len(slots) == len(set(slots))


def test_capacity_drops():
    """With a tiny capacity factor, exactly cap tokens survive per expert."""
    t, e, k = 256, 2, 1
    logits = jnp.zeros((t, e)).at[:, 0].set(10.0)  # everyone wants expert 0
    cap = 8
    g = top_k_gating(logits, k, cap)
    kept = (~np.asarray(g.dropped)[:, 0]) & (np.asarray(g.expert_idx)[:, 0] == 0)
    assert kept.sum() == cap


@given(t=st.sampled_from([16, 64]), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_dispatch_backends_equivalent(t, e, k, seed):
    """einsum (oracle) and scatter (production) dispatch/combine agree."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, 16))
    logits = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, e))
    cap = capacity(t, e, k, 2.0)
    g = top_k_gating(logits, k, cap)
    b1 = D.dispatch_einsum(x, g, e, cap)
    b2 = D.dispatch_scatter(x, g, e, cap)
    np.testing.assert_allclose(b1, b2, atol=1e-5)
    buf = jax.random.normal(jax.random.PRNGKey(seed + 2), (e, cap, 16))
    y1 = D.combine_einsum(buf, g, e, cap)
    y2 = D.combine_scatter(buf, g, e, cap)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_dispatch_combine_roundtrip(seed):
    """combine(dispatch(x)) with identity experts == gate-weighted x for
    non-dropped tokens (the residual invariant the MoE layer relies on)."""
    t, e, k, d = 32, 8, 2, 8
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, d))
    logits = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, e))
    cap = capacity(t, e, k, 4.0)
    g = top_k_gating(logits, k, cap)
    buf = D.dispatch_scatter(x, g, e, cap)
    y = D.combine_scatter(buf, g, e, cap)
    w = np.where(np.asarray(g.dropped), 0, np.asarray(g.gate_weights)).sum(-1)
    np.testing.assert_allclose(np.asarray(y), w[:, None] * np.asarray(x),
                               atol=1e-4)


def test_aux_loss_balanced_lower_than_skewed():
    t, e = 512, 8
    balanced = jax.random.normal(jax.random.PRNGKey(0), (t, e)) * 0.01
    skewed = jnp.zeros((t, e)).at[:, 0].set(8.0)
    cap = capacity(t, e, 1, 2.0)
    a_b = float(top_k_gating(balanced, 1, cap).aux_loss)
    a_s = float(top_k_gating(skewed, 1, cap).aux_loss)
    assert a_s > a_b
