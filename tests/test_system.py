"""End-to-end system behaviour: the serving runtime's two-phase scheduling
(paper §5/§6.2) driving real model weights, and the HLO analysis layer the
roofline reporting depends on."""
import numpy as np

from repro.configs import get_config
from repro.core.popularity import PathProfile
from repro.launch.hlo_analysis import collective_summary, wire_bytes
from repro.models import lm as lm_mod
from repro.runtime.server import MoEServer, ServerConfig

import jax


def test_server_two_phase_end_to_end():
    cfg = get_config("gpt2-moe").smoke()
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    n_moe = cfg.n_moe_layers
    prof = PathProfile(n_layers=n_moe, n_experts=cfg.moe.n_experts, path_len=2)
    server = MoEServer(cfg, params, prof, ServerConfig(path_len=2))
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 16))
    logits, stats = server.serve(toks)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(logits.astype(np.float32)).all()
    assert len(stats) == n_moe
    for s in stats:
        np.testing.assert_allclose(s.actual_pop.sum(), 1.0, atol=1e-6)
        assert s.device_load.shape == (cfg.moe.n_experts,)


def test_server_uniform_vs_lina_balance():
    """With skewed gating, Lina's plan must balance device load better than
    the uniform (DeepSpeed) placement — the core of paper Fig. 16."""
    cfg = get_config("gpt2-moe").smoke()
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    # skew the router so one expert dominates (inference-style skew, Fig. 6)
    router = np.array(params.stack.moe.router)
    router[..., 0] += 2.0
    import jax.numpy as jnp
    stack = params.stack._replace(
        moe=params.stack.moe._replace(router=jnp.asarray(router)))
    params = params._replace(stack=stack)
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 32))

    def max_load(policy):
        srv = MoEServer(cfg, params, prof,
                        ServerConfig(path_len=2, schedule_policy=policy))
        _, stats = srv.serve(toks)
        return np.mean([s.device_load.max() for s in stats])

    assert max_load("lina") <= max_load("uniform") + 1e-9


def test_server_numerics_match_forward():
    """The serving loop's layer-by-layer execution reproduces the one-shot
    prefill logits (capacity raised so no tokens drop: the server's dense
    evaluation has no capacity limit, the SPMD path does)."""
    import dataclasses
    cfg = get_config("gpt2-moe").smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    prof = PathProfile(n_layers=cfg.n_moe_layers,
                       n_experts=cfg.moe.n_experts, path_len=2)
    server = MoEServer(cfg, params, prof,
                       ServerConfig(path_len=2, top_k=cfg.moe.top_k))
    toks = np.random.RandomState(1).randint(0, cfg.vocab_size, (2, 16))
    logits, _ = server.serve(toks)
    import jax.numpy as jnp
    pre = lm_mod.forward_prefill(None, cfg, params,
                                 {"tokens": jnp.asarray(toks)})
    np.testing.assert_allclose(logits, np.asarray(pre.logits),
                               atol=5e-2, rtol=5e-2)


# --- HLO analysis layer ------------------------------------------------------

SAMPLE_HLO = """
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %ar = f32[8,8] all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %ag = f32[128,8] all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  %t0 = (s32[], f32[8,8]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_collective_summary_trip_counts():
    s = collective_summary(SAMPLE_HLO)
    # the in-loop all-reduce counts 24x; the top-level all-gather once
    assert s["counts"]["all-reduce"] == 24
    assert s["counts"]["all-gather"] == 1
    ar_one = wire_bytes("all-reduce", 8 * 8 * 4, 16)
    np.testing.assert_allclose(s["wire_bytes"]["all-reduce"], 24 * ar_one)


def test_wire_bytes_model():
    assert wire_bytes("all-reduce", 100, 2) == 100.0       # 2*100*(1/2)
    assert wire_bytes("all-gather", 160, 16) == 150.0      # 160*15/16
    assert wire_bytes("reduce-scatter", 10, 16) == 150.0   # 10*16*15/16
    assert wire_bytes("collective-permute", 42, 4) == 42.0
    assert wire_bytes("all-to-all", 160, 16) == 150.0
