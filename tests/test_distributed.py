"""Distributed-semantics tests: run scenario scripts in SUBPROCESSES with
``--xla_force_host_platform_device_count=8`` so that the main pytest process
(and the smoke tests) keep seeing a single device, per the dry-run rules.

Covers: expert-parallel MoE layer on a real (2,4) mesh (lina vs baseline
numerics), serve-layer plan-honoring dispatch vs the training layer,
prioritized chunked gradient reduction == plain psum, elastic checkpoint
resharding (save on 1x8, restore on 2x4).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_snippet(body: str, timeout=420):
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"stderr:\n{p.stderr[-3000:]}"
    return p.stdout


def test_moe_layer_lina_equals_baseline_on_mesh():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.launch.mesh import mesh_context
        from repro.core import init_moe_params, moe_layer
        from repro.configs.base import MoEConfig
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=32, n_microops=2)
        params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 16))
        with mesh_context(mesh):
            a = jax.jit(lambda x,p: moe_layer(mesh,x,p,cfg,lina=True))(x, params)
            b = jax.jit(lambda x,p: moe_layer(mesh,x,p,cfg,lina=False))(x, params)
        assert np.allclose(a.y, b.y, atol=1e-5), np.abs(a.y-b.y).max()
        assert np.allclose(float(a.aux_loss), float(b.aux_loss), atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_serve_layer_honors_plan_and_matches_training():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.launch.mesh import mesh_context
        from repro.core import init_moe_params, moe_layer, plan_placement, PlanArrays
        from repro.core.serving import serve_moe_layer
        from repro.configs.base import MoEConfig
        cfg = MoEConfig(n_experts=8, top_k=1, d_ff=32, capacity_factor=2.0)
        params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        with mesh_context(mesh):
            ref = jax.jit(lambda x,p: moe_layer(mesh, x.reshape(8,8,16), p, cfg,
                          lina=False, top_k=1))(x, params).y.reshape(64,16)
        for seed in range(3):
            pop = np.random.RandomState(seed).dirichlet(np.ones(8)*0.3)
            plan = plan_placement(pop, 4, max_pack=4)
            assert (plan.n_replicas >= 1).all()
            pa = PlanArrays.from_plan(plan)
            with mesh_context(mesh):
                y, _, _ = jax.jit(lambda x,p,pl: serve_moe_layer(
                    mesh,x,p,cfg,pl,top_k=1))(x, params, pa)
            assert np.allclose(y, ref, atol=1e-4), np.abs(y-ref).max()
        print("OK")
    """)
    assert "OK" in out


def test_prioritized_chunked_reduce_equals_psum():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((8,), ("data",))
        from repro.launch.mesh import mesh_context
        from repro.core.microop import prioritized_chunked_reduce
        grads = {"a": jnp.arange(40, dtype=jnp.float32).reshape(8, 5),
                 "b": jnp.ones((8, 3)) * 2.0}

        def body(g):
            tok = jnp.float32(0.0)
            red = prioritized_chunked_reduce(g, "data", n_chunks=3, after=tok)
            plain = jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g)
            return red, plain

        with mesh_context(mesh):
            red, plain = jax.jit(shard_map(body, mesh=mesh,
                in_specs=({"a": P("data", None), "b": P("data", None)},),
                out_specs=({"a": P("data", None), "b": P("data", None)},)*2,
                check_rep=False))(grads)
        for k in grads:
            assert np.allclose(red[k], plain[k], atol=1e-6), k
        print("OK")
    """)
    assert "OK" in out


def test_train_step_schedules_match_baseline_on_dp_mesh():
    """All four Lina §4 reduction schedules (and bf16 compression) produce
    params numerically matching the explicit-baseline schedule after real
    train steps on a multi-device dp mesh; int8-EF stays within its
    quantization tolerance."""
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.data import DataConfig, SyntheticLM
        from repro.optim.adamw import AdamWConfig, init_opt_state
        from repro.optim import reduce as R
        from repro.launch.mesh import mesh_context
        from repro.launch.steps import make_train_step
        from repro.models import lm as lm_mod

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("gpt2-moe").smoke()
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
        batch = {k: jnp.asarray(v) for k, v in SyntheticLM(dc).batch(0).items()}
        params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
        ocfg = AdamWConfig()
        opt = init_opt_state(params, ocfg)

        def run(sched, comp=None, steps=2):
            # 64KB micro-ops against ~1MB of smoke grads -> the partitioned
            # schedules really compile a multi-chunk chained reduce
            step = jax.jit(make_train_step(cfg, mesh, ocfg, fsdp=False,
                                           microbatches=2, schedule=sched,
                                           partition_bytes=65536,
                                           grad_compression=comp))
            p, o, rs = params, opt, None
            if comp == "int8_ef":
                rs = R.init_reduce_state(params,
                                         R.ReduceConfig(sched, compression=comp))
            with mesh_context(mesh):
                for _ in range(steps):
                    if rs is not None:
                        p, o, m, rs = step(p, o, batch, rs)
                    else:
                        p, o, m = step(p, o, batch)
            return p

        def maxdiff(a, b):
            return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                           - np.asarray(y, np.float32))))
                       for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        ref = run("baseline")
        for sched in ("priority", "priority+partition",
                      "priority+partition+pipeline"):
            d = maxdiff(ref, run(sched))
            assert d < 1e-5, (sched, d)
        d = maxdiff(ref, run("priority+partition", comp="bf16"))
        assert d < 5e-3, ("bf16", d)
        d = maxdiff(ref, run("priority+partition+pipeline", comp="int8_ef"))
        assert d < 5e-3, ("int8_ef", d)
        print("OK")
    """, timeout=900)
    assert "OK" in out


def test_reduce_shard_really_reduces_distinct_grads():
    """The reduction body must actually average GENUINELY per-device
    gradients: each device perturbs its input by axis_index, so a reduce
    that silently skips the collective (or mis-chunks) returns device-local
    values instead of the analytic mean and fails loudly.  (The train-step
    test above runs on replicated grads where a mean-psum is value-wise an
    identity — this test is the one that proves the psum happens.)"""
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import mesh_context
        from repro.optim.reduce import (ReduceConfig, _reduce_shard,
                                        n_chunks_for_bytes)
        mesh = jax.make_mesh((8,), ("data",))
        g = {"w": jnp.arange(96, dtype=jnp.float32).reshape(8, 12) / 7.0,
             "b": jnp.linspace(-1, 1, 24).reshape(8, 3)}
        for sched in ("baseline", "priority", "priority+partition",
                      "priority+partition+pipeline"):
            for comp in (None, "bf16"):
                cfg = ReduceConfig(sched, partition_bytes=64,
                                   compression=comp)
                nc = n_chunks_for_bytes(g, 64) if cfg.partitioned else 1
                assert nc > 1 or not cfg.partitioned

                def body(gg):
                    idx = jax.lax.axis_index("data").astype(jnp.float32)
                    gg = jax.tree.map(lambda x: x + idx, gg)
                    red, _ = _reduce_shard(gg, None, jnp.float32(0.0),
                                           axes=("data",), cfg=cfg,
                                           n_chunks=nc)
                    return red

                with mesh_context(mesh):
                    red = jax.jit(shard_map(
                        body, mesh=mesh,
                        in_specs=({"w": P(), "b": P()},),
                        out_specs={"w": P(), "b": P()},
                        check_rep=False))(g)
                # mean over devices of (g + idx) = g + 3.5; a skipped psum
                # would return g + axis_index (g on device 0) instead
                tol = 0.2 if comp == "bf16" else 1e-5
                for k in g:
                    assert np.allclose(red[k], g[k] + 3.5, atol=tol), \\
                        (sched, comp, k)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_pytree, load_pytree
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        m1 = jax.make_mesh((8,), ("data",))
        t1 = jax.tree.map(lambda a: jax.device_put(
            a, NamedSharding(m1, P("data", None))), tree)
        d = os.path.join(tempfile.mkdtemp(), "ck")
        save_pytree(t1, d)
        # restore onto a DIFFERENT mesh shape (elastic rescale 1x8 -> 2x4)
        m2 = jax.make_mesh((2, 4), ("data", "model"))
        sh = {"w": NamedSharding(m2, P("data", "model"))}
        t2 = load_pytree(d, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(tree["w"]))
        assert t2["w"].sharding.mesh.shape == {"data": 2, "model": 4}
        print("OK")
    """)
    assert "OK" in out


def test_chunked_a2a_equivalence():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((8,), ("model",))
        from repro.launch.mesh import mesh_context
        from repro.core.microop import (all_to_all_ec, all_to_all_ec_inverse,
                                        chunked_all_to_all)
        buf = jax.random.normal(jax.random.PRNGKey(0), (8*8, 16, 4))

        def body(b):
            whole = all_to_all_ec(b, "model")
            parts = jnp.concatenate(chunked_all_to_all(b, "model", 4), axis=1)
            back = all_to_all_ec_inverse(whole, "model", 8)
            return whole, parts, back

        with mesh_context(mesh):
            whole, parts, back = jax.jit(shard_map(body, mesh=mesh,
                in_specs=(P("model", None, None),),
                out_specs=(P("model", None, None),)*3,
                check_rep=False))(buf)
        assert np.allclose(whole, parts, atol=1e-6)
        assert np.allclose(back, buf, atol=1e-6)   # a2a is its own inverse
        print("OK")
    """)
    assert "OK" in out
