"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py).

Every Pallas kernel runs in interpret mode (kernel body executed on CPU)
across a shape/dtype sweep and must match its oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_ffn import grouped_ffn
from repro.kernels.rwkv6 import rwkv6_wkv
from repro.kernels.ssd import ssd_scan
from repro.kernels.topk_gating import topk_gating_fused

KEY = jax.random.PRNGKey(42)


def keys(n):
    return jax.random.split(KEY, n)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("e,t,d,f", [(2, 32, 64, 128), (4, 64, 128, 256),
                                     (1, 16, 256, 128), (8, 128, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ffn_type", ["swiglu", "gelu"])
def test_grouped_ffn(e, t, d, f, dtype, ffn_type):
    k = keys(4)
    x = (jax.random.normal(k[0], (e, t, d)) * 0.3).astype(dtype)
    wi = (jax.random.normal(k[1], (e, d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(k[2], (e, d, f)) * 0.05).astype(dtype)
    wo = (jax.random.normal(k[3], (e, f, d)) * 0.05).astype(dtype)
    got = grouped_ffn(x, wi, wu, wo, ffn_type=ffn_type, block_t=16,
                      block_f=32)
    want = ref.ref_grouped_ffn(x, wi, wu, wo, ffn_type)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("e,t,d,f", [(2, 300, 64, 96), (3, 17, 32, 40),
                                     (1, 130, 64, 200)])
def test_grouped_ffn_ragged_shapes_pad(e, t, d, f):
    """T/F that do not tile the requested blocks pad up instead of
    shrinking the tile (the old path halved bt/bf down to scalar tiles)."""
    k = keys(4)
    x = jax.random.normal(k[0], (e, t, d)) * 0.3
    wi = jax.random.normal(k[1], (e, d, f)) * 0.05
    wu = jax.random.normal(k[2], (e, d, f)) * 0.05
    wo = jax.random.normal(k[3], (e, f, d)) * 0.05
    got = grouped_ffn(x, wi, wu, wo, block_t=128, block_f=128)
    want = ref.ref_grouped_ffn(x, wi, wu, wo, "swiglu")
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_grouped_ffn_gelu_without_up_projection():
    """gelu FFNs pass wu=None; no zeros tensor is built for it."""
    k = keys(3)
    e, t, d, f = 2, 32, 16, 48
    x = jax.random.normal(k[0], (e, t, d)) * 0.3
    wi = jax.random.normal(k[1], (e, d, f)) * 0.05
    wo = jax.random.normal(k[2], (e, f, d)) * 0.05
    got = grouped_ffn(x, wi, None, wo, ffn_type="gelu", block_t=16)
    want = ref.ref_grouped_ffn(x, wi, None, wo, "gelu")
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    with pytest.raises(ValueError):
        grouped_ffn(x, wi, None, wo, ffn_type="swiglu")


@pytest.mark.parametrize("e,m,k_,n", [(2, 37, 24, 41), (4, 64, 16, 64),
                                      (1, 256, 32, 100)])
def test_grouped_matmul(e, m, k_, n):
    from repro.kernels.moe_ffn import grouped_matmul
    kk = keys(2)
    a = jax.random.normal(kk[0], (e, m, k_))
    b = jax.random.normal(kk[1], (e, k_, n))
    got = grouped_matmul(a, b)
    want = jnp.einsum("emk,ekn->emn", a, b)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_grouped_ffn_op_custom_vjp_matches_oracle_grads():
    """The kernel-path backward (dgrad/wgrad as grouped GEMMs) must match
    autodiff through the einsum oracle, for both FFN types."""
    from repro.kernels.ops import grouped_ffn_op
    for ffn_type in ("swiglu", "gelu"):
        k = keys(4)
        e, t, d, f = 2, 24, 16, 32
        x = jax.random.normal(k[0], (e, t, d)) * 0.3
        wi = jax.random.normal(k[1], (e, d, f)) * 0.05
        wu = jax.random.normal(k[2], (e, d, f)) * 0.05 \
            if ffn_type == "swiglu" else None
        wo = jax.random.normal(k[3], (e, f, d)) * 0.05

        gp = jax.grad(lambda a: (grouped_ffn_op(*a, ffn_type,
                                                use_pallas=True) ** 2).sum())(
            (x, wi, wu, wo))
        gr = jax.grad(lambda a: (ref.ref_grouped_ffn(*a, ffn_type)
                                 ** 2).sum())((x, wi, wu, wo))
        for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_block_and_pad_alignment_invariants():
    """Chosen tiles are always hardware-aligned and tile the padded extent;
    ragged extents pad up instead of shrinking the tile (incl. the T=17
    full-extent case, which must not yield an unaligned 17-row tile)."""
    from repro.kernels.tiling import LANE, SUBLANE, block_and_pad
    for n in (5, 16, 17, 50, 100, 128, 130, 256, 300, 1000, 4096):
        for block in (16, 128, 256, 1024):
            for sub in (SUBLANE, LANE):
                b, n_pad = block_and_pad(n, block, sub=sub)
                assert b % sub == 0, (n, block, sub, b)
                assert n_pad % b == 0 and n_pad >= n, (n, block, sub, b, n_pad)
                # padding never exceeds one tile's worth
                assert n_pad - n < b, (n, block, sub, b, n_pad)


@pytest.mark.parametrize("t,e,k", [(64, 8, 1), (128, 16, 2), (32, 4, 2),
                                   (50, 8, 2)])
def test_topk_gating(t, e, k):
    logits = jax.random.normal(keys(1)[0], (t, e))
    idx, w, probs = topk_gating_fused(logits, k, block_t=16)
    ridx, rw, rprobs = ref.ref_topk_gating(logits, k)
    assert (np.asarray(idx) == np.asarray(ridx)).all()
    np.testing.assert_allclose(w, rw, atol=1e-6)
    np.testing.assert_allclose(probs, rprobs, atol=1e-6)


@pytest.mark.parametrize("t,d,e,k", [(64, 16, 8, 2), (50, 32, 4, 1),
                                     (128, 8, 16, 2)])
def test_topk_gating_fused_router(t, d, e, k):
    """Router matmul folded into the kernel == matmul-then-gate oracle."""
    kk = keys(2)
    x = jax.random.normal(kk[0], (t, d))
    router = jax.random.normal(kk[1], (d, e)) * 0.3
    idx, w, probs = topk_gating_fused(x, k, router=router, block_t=16)
    ridx, rw, rprobs = ref.ref_topk_gating(x @ router, k)
    assert (np.asarray(idx) == np.asarray(ridx)).all()
    np.testing.assert_allclose(w, rw, atol=1e-6)
    np.testing.assert_allclose(probs, rprobs, atol=1e-6)


@pytest.mark.parametrize("t,n_rows,d,k", [(32, 40, 16, 2), (64, 72, 8, 1),
                                          (100, 60, 32, 2)])
def test_dispatch_combine_rows(t, n_rows, d, k):
    """The fused scatter/gather kernels vs their jnp oracles, including
    empty rows (-1) and dropped choices."""
    from repro.kernels.dispatch import combine_rows, dispatch_rows
    kk = keys(4)
    x = jax.random.normal(kk[0], (t, d))
    rows = jax.random.randint(kk[1], (t, k), -1, n_rows)
    # de-duplicate destination rows (gating guarantees uniqueness)
    flat = np.full((t * k,), -1, np.int64)
    seen = set()
    for i, r in enumerate(np.asarray(rows).reshape(-1)):
        if r >= 0 and r not in seen:
            flat[i] = r
            seen.add(r)
    rows = jnp.asarray(flat.reshape(t, k), jnp.int32)

    src = np.full((n_rows,), -1, np.int64)
    for i, r in enumerate(flat):
        if r >= 0:
            src[r] = i // k
    src = jnp.asarray(src, jnp.int32)

    buf = dispatch_rows(x, src, block_rows=16)
    np.testing.assert_allclose(buf, ref.ref_dispatch_rows(x, src), atol=1e-6)

    w = jnp.abs(jax.random.normal(kk[2], (t, k)))
    big = jax.random.normal(kk[3], (n_rows, d))
    y = combine_rows(big, rows, w, block_t=16)
    np.testing.assert_allclose(y, ref.ref_combine_rows(big, rows, w),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,s,h,kv,hd", [(1, 64, 2, 2, 32), (2, 128, 4, 2, 32),
                                         (2, 64, 8, 1, 64), (1, 256, 4, 4, 16)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, kv, hd, causal, window, dtype):
    k = keys(3)
    q = (jax.random.normal(k[0], (b, s, h, hd)) * 0.3).astype(dtype)
    kk = (jax.random.normal(k[1], (b, s, kv, hd)) * 0.3).astype(dtype)
    v = (jax.random.normal(k[2], (b, s, kv, hd)) * 0.3).astype(dtype)
    got = flash_attention(q, kk, v, causal=causal, window=window,
                          block_q=32, block_k=32)
    want = ref.ref_attention(q, kk, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype] * 2, rtol=TOL[dtype])


@pytest.mark.parametrize("b,t,h,hd,chunk", [(1, 32, 2, 16, 8),
                                            (2, 64, 2, 32, 16),
                                            (2, 48, 4, 16, 16)])
def test_rwkv6(b, t, h, hd, chunk):
    k = keys(5)
    r = jax.random.normal(k[0], (b, t, h, hd)) * 0.3
    kk = jax.random.normal(k[1], (b, t, h, hd)) * 0.3
    v = jax.random.normal(k[2], (b, t, h, hd)) * 0.3
    w = -jnp.exp(jax.random.normal(k[3], (b, t, h, hd)) * 0.5)
    u = jax.random.normal(k[4], (h, hd)) * 0.3
    got = rwkv6_wkv(r, kk, v, w, u, chunk=chunk)
    want = ref.ref_rwkv6(r, kk, v, w, u)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,t,h,p,n,chunk", [(1, 32, 2, 16, 8, 8),
                                             (2, 64, 2, 32, 16, 16),
                                             (2, 48, 4, 16, 8, 16)])
def test_ssd(b, t, h, p, n, chunk):
    k = keys(4)
    x = jax.random.normal(k[0], (b, t, h, p)) * 0.3
    dt = jax.random.normal(k[1], (b, t, h)) * 0.5
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    bb = jax.random.normal(k[2], (b, t, n)) * 0.3
    cc = jax.random.normal(k[3], (b, t, n)) * 0.3
    d = jnp.ones((h,))
    got = ssd_scan(x, dt, a_log, bb, cc, d, chunk=chunk)
    want = ref.ref_ssd(x, dt, a_log, bb, cc, d)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_model_ssd_chunked_matches_naive():
    """The model's chunked SSD (models/ssm.py) is itself oracle-checked."""
    from repro.models.ssm import ssd_chunked
    k = keys(4)
    b, t, h, p, n = 2, 64, 2, 16, 8
    x = jax.random.normal(k[0], (b, t, h, p)) * 0.3
    dt = jax.random.normal(k[1], (b, t, h)) * 0.5
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    bb = jax.random.normal(k[2], (b, t, n)) * 0.3
    cc = jax.random.normal(k[3], (b, t, n)) * 0.3
    d = jnp.ones((h,))
    got, _ = ssd_chunked(x, dt, a_log, bb, cc, d, chunk=16)
    want = ref.ref_ssd(x, dt, a_log, bb, cc, d)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_model_wkv_chunked_matches_naive():
    from repro.models.rwkv import wkv_chunked
    k = keys(5)
    b, t, h, hd = 2, 64, 2, 16
    r = jax.random.normal(k[0], (b, t, h * hd)) * 0.3
    kk = jax.random.normal(k[1], (b, t, h * hd)) * 0.3
    v = jax.random.normal(k[2], (b, t, h * hd)) * 0.3
    w = -jnp.exp(jax.random.normal(k[3], (b, t, h * hd)) * 0.5)
    u = jax.random.normal(k[4], (h * hd,)) * 0.3
    got, _ = wkv_chunked(r, kk, v, w, u, h, hd, chunk=16)
    want = ref.ref_rwkv6(*(a.reshape(b, t, h, hd) for a in (r, kk, v, w)),
                         u.reshape(h, hd)).reshape(b, t, h * hd)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
