"""Substrate tests: data determinism, optimizer, compression, checkpoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import DataConfig, SyntheticLM, make_batch_iterator, Prefetcher
from repro.optim.adamw import (AdamWConfig, adamw_update, clip_by_global_norm,
                               cosine_schedule, init_opt_state)
from repro.optim.compression import (Int8State, compress_bf16, compress_int8_ef,
                                     decompress_bf16, decompress_int8,
                                     init_int8_state)


# --- data -------------------------------------------------------------------

def test_data_deterministic():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch(3)
    b = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding():
    base = dict(vocab_size=128, seq_len=16, global_batch=8, seed=7)
    h0 = SyntheticLM(DataConfig(**base, n_hosts=2, host_id=0)).batch(0)
    h1 = SyntheticLM(DataConfig(**base, n_hosts=2, host_id=1)).batch(0)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    it = Prefetcher(make_batch_iterator(cfg), depth=2)
    ref = SyntheticLM(cfg)
    for step in range(5):
        got = next(it)
        np.testing.assert_array_equal(got["tokens"], ref.batch(step)["tokens"])
    it.close()


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == b["labels"].shape


# --- optimizer ---------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=1000)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 0.1
    assert int(state.step) == 50


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(jnp.array(0), cfg)) == 0.0
    assert float(cosine_schedule(jnp.array(10), cfg)) == pytest.approx(1.0)
    assert float(cosine_schedule(jnp.array(100), cfg)) == pytest.approx(0.0, abs=1e-6)


def test_opt_state_dtype():
    cfg = AdamWConfig(state_dtype="bfloat16")
    st = init_opt_state({"w": jnp.zeros((4,), jnp.float32)}, cfg)
    assert st.m["w"].dtype == jnp.bfloat16


# --- gradient compression ----------------------------------------------------

def test_bf16_compression_roundtrip():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (128,))}
    out = decompress_bf16(compress_bf16(g), g)
    assert out["w"].dtype == jnp.float32
    np.testing.assert_allclose(out["w"], g["w"], atol=1e-2)


def test_int8_error_feedback_reduces_bias():
    """Error feedback: the *accumulated* quantization error stays bounded
    (residual carries what each round dropped)."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,)) * 1e-3}
    state = init_int8_state(g)
    total_sent = jnp.zeros((256,))
    for i in range(20):
        (q, s), state = compress_int8_ef(g, state)
        total_sent = total_sent + decompress_int8(q, s)["w"]
    # mean of sent messages ~ true gradient (bias vanishes with EF)
    np.testing.assert_allclose(total_sent / 20, g["w"], atol=5e-5)


# --- checkpoint ---------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (8, 4)),
                      "b": jnp.zeros((4,), jnp.bfloat16)},
            "step": jnp.array(17, jnp.int32)}


def test_checkpoint_roundtrip_bitwise(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    r = load_pytree(str(tmp_path / "ck"), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        # bytes-level compare (numpy has no `equal` ufunc for bfloat16)
        assert a.tobytes() == b.tobytes()


def test_checkpoint_manager_keep_k(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        cm.save(s, _tree(s))
    assert cm.steps() == [30, 40]
    assert cm.latest_step() == 40
    step, state = cm.restore_latest(_tree())
    assert step == 40


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_pytree(_tree(), str(tmp_path / "ck"))
    bad = _tree()
    bad["layer"]["w"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        load_pytree(str(tmp_path / "ck"), bad)


def test_checkpoint_atomic_no_partial(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, _tree())
    # a stale tmp dir from a crashed writer must not confuse discovery
    import os
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert cm.latest_step() == 1
