"""Weighted zero-migration replica routing (Lina §5/§6.2).

Property tests for the serving-side replica split introduced with the
fused routing kernels: integer weight apportionment (token conservation,
±1 targets, slot_cap clamp), fused-vs-XLA exactness of the routing kernels
(ties and all-dropped included), the numpy telemetry mirror agreeing with
the jnp path, the route_to_slots pad-column clamp on stacked plans with
heterogeneous per-layer replica counts, and end-to-end backend parity of
``serve_moe_layer`` in weighted mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.core import init_moe_params
from repro.core.placement import identity_plan, plan_placement, route_weights
from repro.core.serving import (PlanArrays, integer_route_weights,
                                replica_token_counts, route_to_slots,
                                serve_moe_layer, slot_capacity,
                                stack_plan_arrays, uniform_route_weight)
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.kernels.dispatch import weighted_route
from repro.kernels.topk_gating import topk_positions


def _rand_plan(e, n_dev, seed, max_pack=2):
    pop = np.random.RandomState(seed).dirichlet(np.ones(e) * 0.4)
    return plan_placement(pop, n_dev, max_pack=max_pack)


# ------------------------------------------------- integer weight split --

@settings(max_examples=30, deadline=None)
@given(e=st.integers(2, 12), seed=st.integers(0, 10_000),
       slot_cap=st.sampled_from([8, 16, 48]))
def test_integer_weights_conserve_tokens(e, seed, slot_cap):
    """Row sums cover the realized counts whenever the live replicas have
    the headroom; every entry is in [0, slot_cap]; dead columns stay 0."""
    rng = np.random.RandomState(seed)
    plan = _rand_plan(e, max(2, e // 2), seed)
    rw = route_weights(plan)
    counts = rng.randint(0, 3 * slot_cap, size=e).astype(np.int32)
    w = integer_route_weights(counts, rw, plan.n_replicas, slot_cap, xp=np)
    # liveness as the function defines it: by n_replicas (clamped to >= 1 —
    # a fully shed expert still gets a fallback column; weighted_route
    # drops its tokens on the -1 slot id, so nothing mis-routes)
    live = (np.arange(rw.shape[1])[None, :]
            < np.clip(plan.n_replicas, 1, rw.shape[1])[:, None])
    assert w.min() >= 0 and w.max() <= slot_cap
    assert (w[~live] == 0).all()
    room = slot_cap * live.sum(1)
    covered = np.minimum(counts, room)
    assert (w.sum(1) >= covered).all(), (w.sum(1), covered)


@settings(max_examples=30, deadline=None)
@given(e=st.integers(2, 10), seed=st.integers(0, 10_000))
def test_integer_weights_near_fractional_targets(e, seed):
    """Unclamped apportionment stays within +-1 of counts * frac
    (largest-remainder property)."""
    rng = np.random.RandomState(seed)
    plan = _rand_plan(e, max(2, e // 2), seed)
    rw = route_weights(plan)
    slot_cap = 1 << 20                     # never clamps
    counts = rng.randint(0, 500, size=e).astype(np.int32)
    w = integer_route_weights(counts, rw, plan.n_replicas, slot_cap, xp=np)
    live = (np.arange(rw.shape[1])[None, :]
            < np.clip(plan.n_replicas, 1, rw.shape[1])[:, None])
    frac = np.where(live, rw, 0.0)
    tot = frac.sum(1, keepdims=True)
    n_live = np.maximum(live.sum(1, keepdims=True), 1)
    uniform = np.where(live, 1.0 / n_live, 0.0)
    frac = np.where(tot > 1e-9, frac / np.maximum(tot, 1e-9), uniform)
    quota = counts[:, None] * frac
    assert (np.abs(w - quota)[live] <= 1.0 + 1e-5).all()
    assert (w.sum(1) == counts).all()      # exact with infinite headroom


@settings(max_examples=20, deadline=None)
@given(e=st.integers(2, 10), seed=st.integers(0, 10_000))
def test_integer_weights_np_matches_jnp(e, seed):
    rng = np.random.RandomState(seed)
    plan = _rand_plan(e, max(2, e // 2), seed)
    rw = route_weights(plan)
    counts = rng.randint(0, 100, size=e).astype(np.int32)
    w_np = integer_route_weights(counts, rw, plan.n_replicas, 16, xp=np)
    w_j = integer_route_weights(jnp.asarray(counts), jnp.asarray(rw),
                                jnp.asarray(plan.n_replicas), 16)
    assert (np.asarray(w_j) == w_np).all()


def test_integer_weights_zero_weight_rows_fall_back_uniform():
    """An all-zero route_weight row (degenerate table) splits uniformly
    instead of dropping every token."""
    nr = np.array([3, 2], np.int32)
    rw = np.array([[0.0, 0.0, 0.0], [0.5, 0.5, 0.0]], np.float32)
    counts = np.array([9, 4], np.int32)
    w = integer_route_weights(counts, rw, nr, 8, xp=np)
    assert (w[0] == np.array([3, 3, 3])).all()
    assert w[1].sum() == 4 and w[1, 2] == 0


# ------------------------------------------------- fused routing kernels --

def _route_case(seed, t=192, k=2, e=6, r_w=3, slot_cap=16):
    rng = np.random.RandomState(seed)
    idx = rng.randint(-1, e, size=(t, k)).astype(np.int32)
    pos = np.asarray(ref.ref_topk_positions(jnp.asarray(np.maximum(idx, 0)),
                                            e))
    w_int = rng.randint(0, slot_cap + 1, size=(e, r_w)).astype(np.int32)
    cum = np.cumsum(w_int, axis=1).astype(np.int32)
    slot_of = rng.permutation(e * r_w).reshape(e, r_w).astype(np.int32)
    slot_of[rng.random(size=(e, r_w)) < 0.2] = -1
    return idx, pos, cum, slot_of, slot_cap


@pytest.mark.parametrize("seed", range(4))
def test_weighted_route_kernel_matches_ref(seed):
    idx, pos, cum, slot_of, slot_cap = _route_case(seed)
    want = ref.ref_weighted_route(jnp.asarray(idx), jnp.asarray(pos),
                                  jnp.asarray(cum), jnp.asarray(slot_of),
                                  slot_cap)
    got = weighted_route(jnp.asarray(idx), jnp.asarray(pos),
                         jnp.asarray(cum), jnp.asarray(slot_of), slot_cap,
                         block_t=64, interpret=True)
    assert (np.asarray(got) == np.asarray(want)).all()
    # and the numpy mirror agrees bit for bit
    got_np = ref.ref_weighted_route(idx, pos, cum, slot_of, slot_cap, xp=np)
    assert (got_np == np.asarray(want)).all()


def test_weighted_route_ties_and_all_dropped():
    # ties: every replica bin boundary equal (zero-width bins) -> all the
    # tokens land in the single non-empty bin or drop past the total
    e, r_w, slot_cap = 3, 3, 4
    cum = np.tile(np.array([[4, 4, 4]], np.int32), (e, 1))  # only bin 0 live
    slot_of = np.arange(e * r_w, dtype=np.int32).reshape(e, r_w)
    idx = np.array([[0], [0], [0], [0], [0], [1]], np.int32)
    pos = np.array([[0], [1], [2], [3], [4], [0]], np.int32)
    out = np.asarray(weighted_route(jnp.asarray(idx), jnp.asarray(pos),
                                    jnp.asarray(cum), jnp.asarray(slot_of),
                                    slot_cap, interpret=True))
    want = np.asarray(ref.ref_weighted_route(
        jnp.asarray(idx), jnp.asarray(pos), jnp.asarray(cum),
        jnp.asarray(slot_of), slot_cap))
    assert (out == want).all()
    assert (out[:4, 0] == slot_of[0, 0] * slot_cap + pos[:4, 0]).all()
    assert out[4, 0] == -1                       # pos >= total weight
    # all dropped: -1 experts and zero weights
    cum0 = np.zeros((e, r_w), np.int32)
    idx2 = np.full((5, 2), -1, np.int32)
    out2 = np.asarray(weighted_route(jnp.asarray(idx2),
                                     jnp.zeros((5, 2), jnp.int32),
                                     jnp.asarray(cum0),
                                     jnp.asarray(slot_of), slot_cap,
                                     interpret=True))
    assert (out2 == -1).all()


@pytest.mark.parametrize("seed", range(3))
def test_topk_positions_kernel_matches_ref(seed):
    rng = np.random.RandomState(seed)
    t, k, e = 200, 2, 7
    idx = rng.randint(0, e, size=(t, k)).astype(np.int32)
    want = np.asarray(ref.ref_topk_positions(jnp.asarray(idx), e))
    got = np.asarray(topk_positions(jnp.asarray(idx), e, block_t=64,
                                    interpret=True))
    assert (got == want).all()
    # choice-major priority: all 1st choices outrank 2nd choices
    np_mirror = np.asarray(
        ref.ref_topk_positions(jnp.asarray(idx), e))
    assert (np_mirror == want).all()


def test_routing_ops_xla_pallas_parity():
    idx, pos, cum, slot_of, slot_cap = _route_case(11)
    a = kernel_ops.weighted_route_op(jnp.asarray(idx), jnp.asarray(pos),
                                     jnp.asarray(cum), jnp.asarray(slot_of),
                                     slot_cap, use_pallas=False)
    b = kernel_ops.weighted_route_op(jnp.asarray(idx), jnp.asarray(pos),
                                     jnp.asarray(cum), jnp.asarray(slot_of),
                                     slot_cap, use_pallas=True)
    assert (np.asarray(a) == np.asarray(b)).all()
    e = 7
    ridx = jnp.asarray(np.random.RandomState(3).randint(
        0, e, size=(96, 2)).astype(np.int32))
    pa = kernel_ops.topk_positions_op(ridx, e, use_pallas=False)
    pb = kernel_ops.topk_positions_op(ridx, e, use_pallas=True)
    assert (np.asarray(pa) == np.asarray(pb)).all()


# ------------------------------------------- stacked / clamped plans ------

def test_route_to_slots_clamps_stacked_pad_columns():
    """Regression (PR-7 satellite): a stacked PlanArrays right-pads narrow
    replica tables with -1; a layer whose n_replicas exceeds its own live
    width must never index a pad column into a bogus slot."""
    e = 4
    wide = _rand_plan(e, 4, seed=0, max_pack=2)      # replica width 4
    narrow = identity_plan(e, e, max_pack=2)         # width 1
    st_plan = stack_plan_arrays([wide, narrow])
    assert st_plan.replica_of.shape == st_plan.route_weight.shape
    # narrow layer, padded to the wide width: positions sweep far past it
    layer = jax.tree.map(lambda a: a[1], st_plan)
    idx = jnp.tile(jnp.arange(e, dtype=jnp.int32)[:, None], (8, 2))
    pos = jnp.tile(jnp.arange(8, dtype=jnp.int32).repeat(e)[:, None], (1, 2))
    slots = np.asarray(route_to_slots(idx, pos, layer))
    n_slots = int(np.asarray(layer.slot_expert).size)
    assert ((slots >= 0) & (slots < n_slots)).all(), slots
    # inconsistent plan (n_replicas past the live table) -> -1, not a pad id
    bad = PlanArrays(layer.slot_expert,
                     jnp.where(jnp.arange(layer.replica_of.shape[1]) < 1,
                               layer.replica_of, -1),
                     jnp.full((e,), 3, jnp.int32), layer.route_weight)
    s2 = np.asarray(route_to_slots(idx, pos, bad))
    assert set(np.unique(s2)) <= set(range(-1, n_slots))


def test_stacked_route_weights_pad_zero_and_rows_normalize():
    e = 4
    plans = [_rand_plan(e, 4, seed=s, max_pack=2) for s in range(2)] \
        + [identity_plan(e, e, max_pack=2)]
    st_plan = stack_plan_arrays(plans)
    rw = np.asarray(st_plan.route_weight)
    ro = np.asarray(st_plan.replica_of)
    assert (rw[ro < 0] == 0).all()
    np.testing.assert_allclose(rw.sum(-1), 1.0, atol=1e-5)


def test_uniform_route_weight_matches_live_columns():
    ro = jnp.asarray([[0, 1, -1], [2, -1, -1]], jnp.int32)
    nr = jnp.asarray([2, 1], jnp.int32)
    w = np.asarray(uniform_route_weight(ro, nr))
    np.testing.assert_allclose(w, [[0.5, 0.5, 0.0], [1.0, 0.0, 0.0]])


# --------------------------------------------------- telemetry mirror -----

@pytest.mark.parametrize("mode", ["weighted", "round_robin"])
def test_replica_token_counts_bounded_by_capacity(mode):
    e, t, k = 6, 256, 2
    plan = _rand_plan(e, 4, seed=5)
    pa = PlanArrays.from_plan(plan)
    idx = np.random.RandomState(7).randint(0, e, size=(t, k)).astype(np.int32)
    cap = 48
    sc = slot_capacity(cap, int(plan.n_replicas.min()))
    loads = replica_token_counts(idx, pa, cap, sc, route_mode=mode)
    assert loads.shape == (int(np.asarray(pa.slot_expert).size),)
    assert loads.max() <= sc
    kept_floor = min(t * k, e * cap)
    assert 0 < loads.sum() <= kept_floor
    # marking half the tokens invalid only removes their counts
    valid = np.arange(t) % 2 == 0
    lv = replica_token_counts(idx, pa, cap, sc, valid=valid, route_mode=mode)
    assert (lv <= loads).all() and lv.sum() < loads.sum()


def test_weighted_mirror_tracks_route_weight_skew():
    """A heavily skewed route_weight table shows up in the mirror: the
    favored replica of a 2-replica expert carries more tokens."""
    e = 2
    plan = plan_placement(np.array([0.5, 0.5]), 2, max_pack=1)
    assert plan.n_replicas.max() >= 1
    ro = np.asarray(plan.replica_of)
    two = int(np.argmax(plan.n_replicas)) if plan.n_replicas.max() > 1 \
        else None
    pa = PlanArrays(jnp.asarray(plan.slot_expert), jnp.asarray(ro),
                    jnp.asarray(plan.n_replicas),
                    jnp.asarray(np.where(ro >= 0, 1.0, 0.0)
                                / np.maximum(plan.n_replicas, 1)[:, None]))
    idx = np.zeros((64, 1), np.int32)     # everything to expert 0
    sc = slot_capacity(64, 1)
    base = replica_token_counts(idx, pa, 64, sc, route_mode="weighted")
    if two == 0:
        skew = np.asarray(pa.route_weight).copy()
        skew[0] = np.where(ro[0] >= 0, 0.0, 0.0)
        skew[0, 0] = 1.0
        pa2 = pa._replace(route_weight=jnp.asarray(skew))
        l2 = replica_token_counts(idx, pa2, 64, sc, route_mode="weighted")
        assert l2[ro[0, 0]] >= base[ro[0, 0]]
    assert base.sum() == 64


# ------------------------------------------------- end-to-end parity ------

def _cfg(backend):
    return MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=1.25,
                     compute_backend=backend)


@pytest.mark.parametrize("mode", ["weighted", "round_robin"])
def test_serve_backend_parity_per_mode(mode):
    params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    for seed in range(2):
        pop = np.random.RandomState(seed).dirichlet(np.ones(4) * 0.3)
        plan = plan_placement(pop, 2, max_pack=2)
        pa = PlanArrays.from_plan(plan)
        mr = int(plan.n_replicas.min())
        y1, e1, _ = jax.jit(lambda x, p, pl: serve_moe_layer(
            None, x, p, _cfg("xla"), pl, top_k=2, min_replicas=mr,
            route_mode=mode))(x, params, pa)
        y2, e2, _ = jax.jit(lambda x, p, pl: serve_moe_layer(
            None, x, p, _cfg("pallas"), pl, top_k=2, min_replicas=mr,
            route_mode=mode))(x, params, pa)
        np.testing.assert_allclose(y1, y2, atol=1e-5)
        assert (np.asarray(e1) == np.asarray(e2)).all()


def test_serve_weighted_matches_round_robin_at_ample_capacity():
    """With capacity ample enough that nothing drops, both modes combine
    exactly the same expert outputs — the split only changes which replica
    computes a token, never the math (zero-migration invariant)."""
    params = init_moe_params(jax.random.PRNGKey(1), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 16)) * 0.5
    pop = np.random.RandomState(0).dirichlet(np.ones(4))
    plan = plan_placement(pop, 2, max_pack=2)
    pa = PlanArrays.from_plan(plan)
    mr = int(plan.n_replicas.min())
    kw = dict(top_k=2, min_replicas=mr, cap_override=64)
    yw, _, _ = serve_moe_layer(None, x, params, _cfg("xla"), pa,
                               route_mode="weighted", **kw)
    yr, _, _ = serve_moe_layer(None, x, params, _cfg("xla"), pa,
                               route_mode="round_robin", **kw)
    np.testing.assert_allclose(np.asarray(yw), np.asarray(yr), atol=1e-6)
