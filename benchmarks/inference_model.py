"""Inference-side timing model (paper Figs. 16-18, Table 5).

Per MoE layer the end-to-end time is bounded by the *most loaded* device
(paper §2.2: tokens to less-popular experts wait for the stragglers):

  t_layer = gate + a2a(max link) + FFN(max device tokens) + a2a + sched

where device loads come from the PlacementPlan and the scheduler overhead
follows the paper's §7.3.1 measurements (phase-1 overlapped; phase-2
blocking when fine-tuning triggers).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import HardwareConfig, V5E

PHASE2_CHECK_S = 1.45e-3     # paper: resume-signal path
PHASE2_REPLAN_S = 6.2e-3     # paper: full re-schedule path


@dataclass(frozen=True)
class InferenceLayerModel:
    d_model: int
    d_ff: int
    ffn_mult: int
    n_devices: int
    hw: HardwareConfig = V5E

    def layer_time(self, n_tokens: int, max_load_share: float,
                   finetuned: bool = False, lina: bool = True,
                   post_gate_schedule: bool = False) -> float:
        max_tok = n_tokens * max_load_share
        ffn = 2.0 * max_tok * self.d_model * self.d_ff * self.ffn_mult \
            / (self.hw.peak_flops * self.hw.sim_efficiency)
        link = self.hw.ici_bw * self.hw.ici_links
        a2a = 2.0 * max_tok * self.d_model * 2 / link   # both directions
        t = ffn + a2a
        if lina:
            t += PHASE2_REPLAN_S if finetuned else PHASE2_CHECK_S
        if post_gate_schedule:
            # scheduling only after gating blocks every layer (paper's
            # 'w/o estimation' ablation, §7.3.1)
            t += PHASE2_REPLAN_S
        return t

    def ideal_time(self, n_tokens: int) -> float:
        return self.layer_time(n_tokens, 1.0 / self.n_devices, lina=False)
