"""Inference-side benchmarks: Figs. 16-19, Tables 5-6.

The two-phase Server runs real (smoke-scale) model weights whose routers are
skewed to reproduce the paper's inference-time expert popularity (Fig. 6);
per-layer device loads feed the v5e latency model (inference_model.py) and
times are normalized to Ideal (perfectly balanced), exactly as the paper
reports.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.inference_model import InferenceLayerModel
from repro.configs import TRANSFORMER_XL, BERT_LARGE, with_experts
from repro.configs.base import A100_IB

# the latency model runs at PAPER scale (full model dims, paper batch) —
# only the dimensionless quantities (loads, fine-tune flags, accuracy) come
# from the smoke-scale serve execution
MODEL_TOKENS = 32768
from repro.core.popularity import PathProfile
from repro.data import DataConfig, SyntheticLM
from repro.models import lm as lm_mod
from repro.obs import ObsContext
from repro.runtime.engine import (EngineConfig, ServingEngine, simulate,
                                  summarize_results)
from repro.runtime.server import MoEServer, ServerConfig, profile_from_training

MODELS = {"transformer-xl": TRANSFORMER_XL, "bert-large": BERT_LARGE}
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _skewed_smoke(base, n_experts: int, seed=0, skew=2.0):
    """Smoke config + params with an inference-style skewed router AND a
    real cross-layer selection pattern: every layer uses the SAME router
    matrix with per-layer column permutations, so a token's expert at layer
    i deterministically indexes its expert at layer i+1 (the §5.2 pattern,
    here by construction instead of by training)."""
    cfg = with_experts(base, n_experts).smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=n_experts))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    router = np.array(params.stack.moe.router, np.float32)
    g = cfg.n_layers // cfg.moe.every
    basis = rng.randn(router.shape[1], n_experts).astype(np.float32) * skew
    basis[:, rng.choice(n_experts, 2, replace=False)] *= 1.5   # hot experts
    for i in range(g):
        perm = rng.permutation(n_experts)
        router[i] = basis[:, perm]
    stack = params.stack._replace(
        moe=params.stack.moe._replace(router=jnp.asarray(router)))
    return cfg, params._replace(stack=stack)


def _replica_imbalance(stats, n_dev: int) -> float:
    """Token-weighted max/mean imbalance of the REALIZED per-device replica
    routing (``LayerStats.replica_load`` aggregated to devices) — the §5
    weighted-split objective as observed post-routing, where device_load
    measures what the plan could do at best.  Weighted routing pushes this
    toward 1.0 on replicated placements; round-robin splits evenly per
    expert and eats whatever co-location skew the plan has."""
    num = den = 0.0
    for s in stats:
        rep = getattr(s, "replica_load", None)
        if rep is None:
            continue
        dev = np.asarray(rep, np.float64).reshape(n_dev, -1).sum(1)
        if dev.sum() <= 0:
            continue
        w = max(s.n_tokens, 1)
        num += w * float(dev.max() / max(dev.mean(), 1e-12))
        den += w
    return num / den if den else 0.0


def _serve_times(cfg, params, scfg: ServerConfig, batches, seq,
                 profile_batches=4, full_cfg=None):
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=4,
                      seed=1)
    ds = SyntheticLM(dcfg)
    prof = profile_from_training(
        cfg, params, (ds.batch(i) for i in range(profile_batches)),
        path_len=scfg.path_len)
    server = MoEServer(cfg, params, prof, scfg)
    fc = full_cfg or cfg
    lm = InferenceLayerModel(fc.d_model, fc.moe.d_ff or fc.d_ff,
                             3 if fc.ffn_type == "swiglu" else 2,
                             server.n_dev, hw=A100_IB)
    times, ideals, fts, accs = [], [], [], []
    all_stats = []
    wall = 0.0
    for b in range(batches):
        batch = ds.batch(500 + b)
        t0 = time.perf_counter()
        _, stats = server.serve(batch["tokens"])
        wall += time.perf_counter() - t0
        all_stats += stats
        n_tok = MODEL_TOKENS
        t = sum(lm.layer_time(
            n_tok, s.device_load.max(), finetuned=s.finetuned,
            lina=scfg.schedule_policy == "lina",
            post_gate_schedule=not scfg.use_estimation) for s in stats)
        ideal = sum(lm.ideal_time(n_tok) for _ in stats)
        times.append(t)
        ideals.append(ideal)
        fts += [s.finetuned for s in stats]
        accs += [s.est_accurate for s in stats]
    norm = np.array(times) / np.maximum(np.array(ideals), 1e-12)
    return {
        "median": float(np.median(norm)),
        "p95": float(np.percentile(norm, 95)),
        "finetune_rate": float(np.mean(fts)),
        "accuracy": float(np.mean(accs)),
        "replica_imbalance": _replica_imbalance(all_stats, server.n_dev),
        "wall_us": wall / batches * 1e6,
    }


def fig16_inference_time(batches=8, seq=64):
    """Figs. 16-18: median/p95 inference time normalized to Ideal for
    Baseline (uniform), Lina, and the two ablations (§7.3.1)."""
    rows = []
    for mname, base in MODELS.items():
        for n_exp in (4, 16):
            cfg, params = _skewed_smoke(base, n_exp)
            full = with_experts(base, n_exp)
            variants = {
                "baseline": ServerConfig(schedule_policy="uniform"),
                "lina": ServerConfig(schedule_policy="lina"),
                "no-estimation": ServerConfig(schedule_policy="lina",
                                              use_estimation=False),
                "no-finetune": ServerConfig(schedule_policy="lina",
                                            use_finetuning=False),
            }
            res = {k: _serve_times(cfg, params, v, batches, seq,
                                   full_cfg=full)
                   for k, v in variants.items()}
            speed_med = res["baseline"]["median"] / res["lina"]["median"]
            speed_p95 = res["baseline"]["p95"] / res["lina"]["p95"]
            rows.append((
                f"fig16/{mname}-{n_exp}e", res["lina"]["wall_us"],
                f"median_speedup={speed_med:.2f},p95_speedup={speed_p95:.2f},"
                f"lina_norm_median={res['lina']['median']:.2f},"
                f"noest_norm_median={res['no-estimation']['median']:.2f},"
                f"noft_norm_p95={res['no-finetune']['p95']:.2f},"
                f"finetune_rate={res['lina']['finetune_rate']:.2f},"
                f"replica_imb={res['lina']['replica_imbalance']:.2f}"))
    return rows


def table5_path_length(batches=6, seq=64):
    rows = []
    cfg, params = _skewed_smoke(TRANSFORMER_XL, 16)
    for path_len in (1, 3, 6):
        r = _serve_times(cfg, params,
                         ServerConfig(schedule_policy="lina",
                                      path_len=path_len), batches, seq,
                         full_cfg=with_experts(TRANSFORMER_XL, 16))
        rows.append((f"table5/txl-16e-l{path_len}", r["wall_us"],
                     f"norm_median={r['median']:.2f},norm_p95={r['p95']:.2f},"
                     f"finetune_rate={r['finetune_rate']:.2f},"
                     f"accuracy={r['accuracy']:.2f}"))
    return rows


def poisson_zipf_trace(cfg, n_requests: int, seq: int, rate_hz: float,
                       seed: int = 0):
    """Open-loop request trace: Poisson arrivals (exponential interarrival
    at ``rate_hz`` requests/s of virtual time) of ``seq``-token requests.
    Expert popularity skew is Zipfian by construction — the `_skewed_smoke`
    router concentrates traffic on a few hot experts (paper Fig. 6), which
    is what stresses placement."""
    rng = np.random.RandomState(seed)
    t = 0.0
    trace = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate_hz)
        trace.append((rng.randint(0, cfg.vocab_size, (seq,)), t))
    return trace


def _hist_ms(met, name: str, q: float, **labels) -> float:
    """Registry histogram quantile in ms (NaN when absent/empty)."""
    h = met.get(name, **labels)
    if h is None or not h.count:
        return float("nan")
    return h.quantile(q) * 1e3


def traffic_skewed_bursty(n_requests=24, seq=48, rate_hz=20.0,
                          profile_batches=4, max_new_tokens=8,
                          json_path: str = "BENCH_traffic.json"):
    """Serving-engine scenario: Zipf-skewed expert popularity + Poisson
    (bursty) arrivals through the continuous-batching engine, each request
    *generating* ``max_new_tokens`` tokens through the incremental
    KV-cache decode path (the paper's §5 latency-bound regime).  Reports
    request latency, TTFT and time-per-output-token p50/p95
    (virtual-clock: queueing from arrivals, service from measured wall
    time), decode throughput, and the plan-cache reuse rate for `lina` vs
    `uniform` scheduling.

    The obs registry the engine publishes into supplies the TTFT
    decomposition (queue / prefill / insert — summing to TTFT on the
    completion clock) and the per-decode-occupancy step-time histograms
    (the TPOT a request sees at that co-residency); both land in the rows
    and in ``json_path`` alongside the admission ledger
    (offered == completed + shed)."""
    cfg, params = _skewed_smoke(TRANSFORMER_XL, 16)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=4,
                      seed=1)
    ds = SyntheticLM(dcfg)
    prof = profile_from_training(
        cfg, params, (ds.batch(i) for i in range(profile_batches)),
        path_len=3)
    rows = []
    jpolicies = {}
    for policy in ("uniform", "lina"):
        obs = ObsContext.disabled()      # fresh registry; spans off
        server = MoEServer(cfg, params, prof,
                           ServerConfig(path_len=3, schedule_policy=policy),
                           obs=obs)
        engine = ServingEngine(server, EngineConfig(max_batch_tokens=4 * seq,
                                                    max_batch_requests=8))
        trace = poisson_zipf_trace(cfg, n_requests, seq, rate_hz, seed=7)
        t0 = time.perf_counter()
        results = simulate(engine, trace, max_new_tokens=max_new_tokens)
        wall = time.perf_counter() - t0
        m = summarize_results(results)
        loads = [s.device_load.max() for s in engine.layer_stats]
        met = obs.metrics
        breakdown = {
            f"{phase}_{pct}_ms": _hist_ms(met, f"engine_ttft_{phase}_s",
                                          q)
            for phase in ("queue", "prefill", "insert")
            for pct, q in (("p50", 0.50), ("p95", 0.95))}
        tpot_occ = {}
        for lk, h in sorted(met.series("engine_decode_step_s").items()):
            occ = dict(lk).get("occupancy", "?")
            tpot_occ[occ] = {"p50_ms": h.quantile(0.50) * 1e3,
                             "p95_ms": h.quantile(0.95) * 1e3,
                             "steps": h.count}
        ledger = {
            "offered": met.value("engine_requests_offered_total"),
            "completed": met.value("engine_requests_completed_total"),
            "shed": sum(c.value for c in
                        met.series("engine_requests_shed_total").values()),
        }
        occ_cols = ",".join(
            f"tpot_occ{occ}_p50_ms={v['p50_ms']:.1f}"
            for occ, v in sorted(tpot_occ.items(), key=lambda kv: int(kv[0])))
        rows.append((
            f"traffic/txl-16e-{policy}", wall / max(len(results), 1) * 1e6,
            f"p50_ms={m['latency_p50']*1e3:.1f},"
            f"p95_ms={m['latency_p95']*1e3:.1f},"
            f"ttft_p50_ms={m['ttft_p50']*1e3:.1f},"
            f"ttft_p95_ms={m['ttft_p95']*1e3:.1f},"
            f"ttft_queue_p50_ms={breakdown['queue_p50_ms']:.1f},"
            f"ttft_prefill_p50_ms={breakdown['prefill_p50_ms']:.1f},"
            f"ttft_insert_p50_ms={breakdown['insert_p50_ms']:.1f},"
            f"tpot_p50_ms={m['tpot_p50']*1e3:.1f},"
            f"tpot_p95_ms={m['tpot_p95']*1e3:.1f},"
            f"{occ_cols},"
            f"gen_tok_s={m['gen_tok_s']:.1f},"
            f"plan_reuse={engine.plan_reuse_rate:.2f},"
            f"finetune_rate={engine.finetune_rate:.2f},"
            f"max_load={np.mean(loads):.3f},"
            f"replica_imb="
            f"{_replica_imbalance(engine.layer_stats, server.n_dev):.2f}"))
        jpolicies[policy] = {
            "wall_us_per_req": wall / max(len(results), 1) * 1e6,
            "latency_p50_ms": m["latency_p50"] * 1e3,
            "latency_p95_ms": m["latency_p95"] * 1e3,
            "ttft_p50_ms": m["ttft_p50"] * 1e3,
            "ttft_p95_ms": m["ttft_p95"] * 1e3,
            "ttft_breakdown_ms": breakdown,
            "tpot_p50_ms": m["tpot_p50"] * 1e3,
            "tpot_p95_ms": m["tpot_p95"] * 1e3,
            "tpot_by_occupancy": tpot_occ,
            "gen_tok_s": m["gen_tok_s"],
            "plan_reuse": engine.plan_reuse_rate,
            "finetune_rate": engine.finetune_rate,
            "ledger": ledger,
            "ledger_closed":
                ledger["offered"] == ledger["completed"] + ledger["shed"],
        }
    if not os.path.isabs(json_path):
        json_path = os.path.join(REPO_ROOT, json_path)
    with open(json_path, "w") as fh:
        json.dump({
            "model": "transformer-xl-16e(smoke)",
            "trace": {"n_requests": n_requests, "seq": seq,
                      "rate_hz": rate_hz, "max_new_tokens": max_new_tokens,
                      "shape": "stationary-poisson+zipf-router"},
            "ttft_identity": "queue + prefill + insert == ttft "
                             "(completion clock; see repro.obs validate)",
            "policies": jpolicies,
        }, fh, indent=1)
    rows.append(("traffic/json", 0.0, json_path))
    return rows


def fig19_estimation_accuracy(batches=6, seq=64):
    """Fig. 19: per-MoE-layer estimation accuracy."""
    cfg, params = _skewed_smoke(TRANSFORMER_XL, 16)
    scfg = ServerConfig(schedule_policy="lina", path_len=3)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=4,
                      seed=1)
    ds = SyntheticLM(dcfg)
    prof = profile_from_training(cfg, params,
                                 (ds.batch(i) for i in range(4)), path_len=3)
    server = MoEServer(cfg, params, prof, scfg)
    per_layer = {}
    for b in range(batches):
        _, stats = server.serve(ds.batch(700 + b)["tokens"])
        for s in stats:
            per_layer.setdefault(s.layer, []).append(s.est_accurate)
    rows = []
    for layer, accs in sorted(per_layer.items()):
        rows.append((f"fig19/txl-16e-layer{layer}", 0.0,
                     f"accuracy={np.mean(accs):.2f}"))
    overall = np.mean([a for v in per_layer.values() for a in v])
    rows.append(("fig19/txl-16e-overall", 0.0, f"accuracy={overall:.2f}"))
    return rows


def overlap_efficiency_infer(device_count=4, steps=5, batch=4, seq=32,
                             variants=None, chunk_counts=(1, 2, 4)):
    """Serve-side overlap efficiency: the forward-only analogue of the
    training overlap microbench (train_side._measure_overlap_inprocess) on
    the forced multi-device CPU mesh — fraction of the inference a2a hidden
    per chunk count per variant (pipelined / pipelined+grouped / shortcut),
    requested *and* chosen chunk counts surfaced as columns."""
    from benchmarks.train_side import OVERLAP_VARIANTS, overlap_rows_subprocess
    rows = []
    for o in overlap_rows_subprocess(
            device_count=device_count, steps=steps, batch=batch, seq=seq,
            variants=variants or OVERLAP_VARIANTS,
            chunk_counts=chunk_counts, mode="infer"):
        rows.append((f"overlap-infer/{o['variant']}"
                     f"-c{o['chunks_requested']}", o["us_per_call"],
                     f"chunks_requested={o['chunks_requested']},"
                     f"chunks_chosen={o['chunks_chosen']},"
                     f"serial_us={o['serial_us']:.1f},"
                     f"a2a_us={o['a2a_us']:.1f},"
                     f"a2a_hidden_frac={o['a2a_hidden_frac']:.3f}"))
    return rows
