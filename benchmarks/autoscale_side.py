"""Autoscale benchmark: static-uniform vs static-lina vs the telemetry-
driven controller under drifting-popularity traffic.

Each trace (``repro.sched.workloads`` scenarios — the rotating topic
mixture and the flash crowd are the two drifting-popularity cases; the
full run adds the diurnal tide) is replayed identically through four
serving variants:

  static-uniform   identity placement, no replication (DeepSpeed layout);
  static-lina      Lina's Eq. 1 placement computed ONCE from the profiled
                   popularity and held fixed — the deployment-time plan
                   the ROADMAP's "static PlacementPlan with a fixed
                   max_pack" names; what drift leaves behind;
  lina-dynamic     the PR-1/PR-2 stack — per-batch two-phase re-planning
                   with the PlanCache's §5.2 drift invalidation (reported
                   for context: it re-fits every batch but pays the
                   paper's blocking phase-2 re-plan on most of them);
  autoscaled       the same stack with an ``AdaptiveScheduler`` attached:
                   per-layer plans come from the telemetry
                   popularity-envelope at the controller's cadence
                   (hysteresis-gated, migration-throttled), the per-batch
                   planner and blocking phase-2 are bypassed.

The acceptance comparison is autoscaled vs the two *static* plans; the
dynamic re-planner rows quantify what per-batch freshness costs in p95.
A fifth run per trace repeats the autoscaled variant with
``route_mode="round_robin"`` — the §5 routing ablation: its
``replica_imbalance`` column (token-weighted max/mean of the realized
per-device replica loads) is what the weighted zero-migration split must
beat on the drifting traces.

Latency methodology: open-loop virtual-clock replay (``engine.simulate``)
with ``time_scale=0`` and a *modeled* per-step service time from
``benchmarks.inference_model`` — per layer, the straggler device's FFN +
a2a time under the plan's realized load (paper §2.2), plus the paper's
scheduler overheads (per-layer phase-2 check / blocking re-plan) for the
``lina-dynamic`` variant (the only one that schedules per batch; the
static variants and the autoscaler never block a layer — except that any
batch the autoscaler's pre-bootstrap window DID fine-tune is charged) and
the expert-weight migration time for controller swaps.  Host wall time is
reported separately (us_per_call) — single-host CPU wall time cannot see
device-load imbalance, which is the quantity under test.

The full run writes ``BENCH_autoscale.json`` (committed); ``--smoke``
writes ``BENCH_autoscale.smoke.json`` (gitignored, uploaded by CI).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from benchmarks.infer_side import _replica_imbalance, _skewed_smoke
from benchmarks.inference_model import InferenceLayerModel
from repro.configs import TRANSFORMER_XL, with_experts
from repro.configs.base import A100_IB
from repro.data import DataConfig, SyntheticLM
from repro.runtime.engine import (EngineConfig, ServingEngine, simulate,
                                  summarize_results)
from repro.runtime.server import MoEServer, ServerConfig, profile_from_training
from repro.sched import (AdaptiveScheduler, ControllerConfig, generate_trace,
                         get_spec)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = "BENCH_autoscale.json"

# the latency model evaluates the measured (dimensionless) device loads at
# paper scale: a full engine micro-batch maps to this many model tokens
MODEL_TOKENS = 32768

N_EXPERTS = 16
MAX_PACK = 3                  # sub-slots per device: a TIGHT slot budget
#                               (48 slots, 16 experts) — adaptivity only
#                               matters when replication is not free
VARIANTS = ("uniform", "lina-static", "lina-dynamic", "autoscaled")


def _make_service_model(full_cfg, n_dev, engine_tokens, *, lina: bool,
                        scheduler=None):
    """Modeled distributed seconds per engine step (see module docstring)."""
    d_ff = full_cfg.moe.d_ff or full_cfg.d_ff
    mult = 3 if full_cfg.ffn_type == "swiglu" else 2
    lm = InferenceLayerModel(full_cfg.d_model, d_ff, mult, n_dev, hw=A100_IB)
    link = A100_IB.ici_bw * A100_IB.ici_links
    expert_bytes = mult * full_cfg.d_model * d_ff * 2        # bf16 stacks
    scale = MODEL_TOKENS / engine_tokens

    def model(stats, n_tokens):
        n_tok = max(1.0, n_tokens * scale)
        # the autoscaled variant (lina=False) has no per-layer scheduler
        # sync — but its pre-bootstrap steps still run the per-batch
        # planner, so a layer that DID block on phase-2 is charged for it
        t = sum(lm.layer_time(n_tok, float(s.device_load.max()),
                              finetuned=s.finetuned,
                              lina=lina or s.finetuned)
                for s in stats)
        if scheduler is not None:
            # weight movement of controller swaps, charged when it happens
            t += scheduler.controller.pop_migration() * expert_bytes / link
        return t

    return model


def _imbalance(stats) -> float:
    """Token-weighted max/mean device-load imbalance: each served layer
    contributes its straggler ratio (max device token share / mean)
    weighted by the tokens it dispatched.  This is exactly proportional to
    the total straggler-link a2a bytes over the run relative to a
    perfectly balanced run — the §5 transfer-balance objective as a single
    number.  (Token weighting keeps one-token decode batches, whose ratio
    is structurally ~n_dev/replicas for ANY scheduler, from drowning the
    signal; a plain time-aggregate would instead launder per-step
    imbalance that happens to rotate across devices.)"""
    num = den = 0.0
    for s in stats:
        load = np.asarray(s.device_load, np.float64)
        w = max(s.n_tokens, 1)
        num += w * float(load.max() / max(load.mean(), 1e-12))
        den += w
    return num / den if den else 0.0


def _early_popularity(stats, n_layers: int, n_experts: int,
                      frac: float = 0.25) -> dict:
    """Per-layer token-weighted popularity over the first ``frac`` of a
    reference run — the freshest popularity a deployment-time (static)
    planner could have observed before the trace drifts away from it."""
    per_layer = {}
    cut = max(1, int(len(stats) * frac))
    for s in list(stats)[:cut]:
        acc = per_layer.setdefault(s.layer, np.zeros((n_experts,)))
        per_layer[s.layer] = acc + np.asarray(s.actual_pop, np.float64) * \
            max(s.n_tokens, 1)
    out = {}
    for li in range(n_layers):
        pop = per_layer.get(li)
        if pop is None or np.sum(pop) <= 0:
            pop = np.ones((n_experts,))
        out[li] = pop / np.sum(pop)
    return out


def _run_variant(variant, cfg, full, params, prof, trace, seq,
                 max_new_tokens, warm, ctrl_kwargs, static_pop=None,
                 route_mode="weighted"):
    from repro.core.placement import plan_placement

    policy = "uniform" if variant == "uniform" else "lina"
    server = MoEServer(cfg, params, prof,
                       ServerConfig(path_len=3, schedule_policy=policy,
                                    max_pack=MAX_PACK,
                                    route_mode=route_mode))
    ecfg = EngineConfig(max_batch_tokens=4 * seq, max_batch_requests=8)
    scheduler = None
    if variant == "autoscaled":
        scheduler = AdaptiveScheduler(server, ControllerConfig(**ctrl_kwargs))
    elif variant == "lina-static":
        # Eq. 1 + FFD from the trace's own EARLY popularity, fixed for the
        # run: the strongest static plan a deployment could have shipped —
        # right when it was built, stale once the workload drifts
        server.publish_plans({
            li: plan_placement(static_pop[li], server.n_dev, MAX_PACK)
            for li in range(cfg.n_moe_layers)})
    engine = ServingEngine(
        server, ecfg, scheduler=scheduler,
        service_model=_make_service_model(
            full, server.n_dev, ecfg.max_batch_tokens,
            lina=(variant == "lina-dynamic"), scheduler=scheduler))
    if warm:
        engine.warmup(seqs=(seq,), max_new_tokens=max_new_tokens,
                      min_replicas_grid=(1, 2, 4))
    t0 = time.perf_counter()
    # record (don't gate) steady-state retraces: warmed variants should
    # drive this to ~0, and the row makes compile stalls visible
    from repro.analysis.retrace import no_retrace
    with no_retrace("autoscale simulate window", strict=False) as retr:
        results = simulate(engine, trace, time_scale=0.0,
                           max_new_tokens=max_new_tokens)
    wall = time.perf_counter() - t0
    m = summarize_results(results)
    out = {
        "p50_ms": m["latency_p50"] * 1e3, "p95_ms": m["latency_p95"] * 1e3,
        "ttft_p95_ms": m["ttft_p95"] * 1e3,
        "imbalance": _imbalance(engine.layer_stats),
        "replica_imbalance": _replica_imbalance(engine.layer_stats,
                                                server.n_dev),
        "finetune_rate": engine.finetune_rate,
        "plan_reuse": engine.plan_reuse_rate,
        "wall_us_per_req": wall / max(len(results), 1) * 1e6,
        "n_completed": len(results),
        "retraces": retr.count,
    }
    if scheduler is not None:
        rep = scheduler.report()
        out.update(swaps=rep["swaps"], bootstraps=rep["bootstraps"],
                   churn_per_100_steps=rep["churn_per_100_steps"],
                   migrated_slots=scheduler.controller.migrated_slots,
                   drift_rates={li: round(l["drift_rate"], 3) for li, l in
                                rep["telemetry"]["layers"].items()})
    return out, engine


def autoscale_benchmark(n_requests=48, seq=32, rate_hz=24.0,
                        max_new_tokens=8, profile_batches=4,
                        traces=("drift", "flash", "diurnal"), warm=True,
                        interval=4, hysteresis=0.1, headroom=1.0,
                        json_path: str = JSON_PATH):
    """One row per (trace, variant) + a verdict row per trace; writes the
    full comparison (specs, per-variant metrics, controller config and
    churn) to ``json_path``."""
    cfg, params = _skewed_smoke(TRANSFORMER_XL, N_EXPERTS)
    full = with_experts(TRANSFORMER_XL, N_EXPERTS)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=4, seed=1)
    ds = SyntheticLM(dcfg)
    prof = profile_from_training(
        cfg, params, (ds.batch(i) for i in range(profile_batches)),
        path_len=3)
    ctrl_kwargs = dict(interval=interval, hysteresis=hysteresis,
                       headroom=headroom, min_observations=2)

    rows = []
    jtraces = {}
    for tname in traces:
        spec = get_spec(tname, n_requests=n_requests, seq=seq,
                        rate_hz=rate_hz, seed=7)
        trace = generate_trace(spec, cfg.vocab_size)
        res = {}
        static_pop = None
        for variant in VARIANTS:
            r, engine = _run_variant(variant, cfg, full, params, prof, trace,
                                     seq, max_new_tokens, warm, ctrl_kwargs,
                                     static_pop=static_pop)
            res[variant] = r
            if variant == "uniform":
                # the static-lina baseline plans from the popularity the
                # trace itself showed early on (its strongest static form)
                static_pop = _early_popularity(
                    engine.layer_stats, cfg.n_moe_layers, cfg.moe.n_experts)
            extra = ""
            if "churn_per_100_steps" in r:
                extra = (f",churn_per_100={r['churn_per_100_steps']:.1f},"
                         f"swaps={r['swaps']}")
            rows.append((
                f"autoscale/{tname}-{variant}", r["wall_us_per_req"],
                f"p50_ms={r['p50_ms']:.1f},p95_ms={r['p95_ms']:.1f},"
                f"imbalance={r['imbalance']:.2f},"
                f"replica_imbalance={r['replica_imbalance']:.2f},"
                f"finetune_rate={r['finetune_rate']:.2f}{extra}"))
        # §5 routing ablation: the same autoscaled stack with positional
        # round-robin replica splits — isolates what the realized-histogram
        # weighted routing buys at zero migration cost
        r_rr, _ = _run_variant("autoscaled", cfg, full, params, prof, trace,
                               seq, max_new_tokens, warm, ctrl_kwargs,
                               route_mode="round_robin")
        res["autoscaled-roundrobin"] = r_rr
        rows.append((f"autoscale/{tname}-autoscaled-roundrobin",
                     r_rr["wall_us_per_req"],
                     f"p95_ms={r_rr['p95_ms']:.1f},"
                     f"replica_imbalance={r_rr['replica_imbalance']:.2f}"))
        auto, stat, uni = res["autoscaled"], res["lina-static"], res["uniform"]
        verdict = {
            "p95_beats_static_uniform": auto["p95_ms"] < uni["p95_ms"],
            "p95_beats_static_lina": auto["p95_ms"] < stat["p95_ms"],
            "imbalance_beats_static_uniform":
                auto["imbalance"] < uni["imbalance"],
            "imbalance_beats_static_lina":
                auto["imbalance"] < stat["imbalance"],
            "replica_imbalance_weighted_beats_rr":
                auto["replica_imbalance"] < r_rr["replica_imbalance"],
        }
        rows.append((f"autoscale/{tname}-verdict", 0.0,
                     ",".join(f"{k}={v}" for k, v in verdict.items())))
        jtraces[tname] = {
            "spec": dataclasses.asdict(spec),
            "variants": res,
            "verdict": verdict,
        }

    if not os.path.isabs(json_path):
        json_path = os.path.join(REPO_ROOT, json_path)
    with open(json_path, "w") as fh:
        json.dump({
            "model": f"transformer-xl-{N_EXPERTS}e(smoke)",
            "n_devices": N_EXPERTS,
            "controller": ctrl_kwargs,
            "latency_model": "inference_model.InferenceLayerModel@A100_IB, "
                             f"{MODEL_TOKENS} tokens per full micro-batch, "
                             "time_scale=0 (modeled service, measured loads)",
            "max_new_tokens": max_new_tokens,
            "warm": warm,
            "traces": jtraces,
        }, fh, indent=1)
    rows.append(("autoscale/json", 0.0, json_path))
    return rows
