"""Training-side benchmarks: Table 1, Figs. 10-15, Table 3, and the
MEASURED schedule ablation (``schedules``).

Each function returns rows of (name, us_per_call, derived).  ``us_per_call``
is a real CPU wall-time of the corresponding smoke-scale jitted step (the
anchor proving the code path runs); ``derived`` carries the v5e-modelled
quantity the paper table reports.

``measured_schedule_ablation`` is different in kind: it runs every Lina §4
gradient-reduction schedule through the REAL jitted train step on a forced
multi-device CPU mesh (``XLA_FLAGS=--xla_force_host_platform_device_count``,
in a subprocess so the parent's jax stays single-device per the dry-run
rules) and reports measured wall time next to the analytic
``simulate_step`` number for the same schedule.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.commmodel import (MoEStepModel, simulate_backward,
                                  simulate_step, step_model_for)
from repro.configs import TRANSFORMER_XL, GPT2_MOE, BERT2GPT2, with_experts
from repro.configs.base import V5E, A100_IB
from repro.core.packing import choose_packing
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, init_opt_state

PAPER_MODELS = {"transformer-xl": TRANSFORMER_XL, "gpt2": GPT2_MOE,
                "bert2gpt2": BERT2GPT2}
SEQ, BATCH = 1024, 64           # paper-scale shapes for the model
SCHEDULES = ["baseline", "priority", "priority+partition",
             "priority+partition+pipeline", "fixed"]
# Reproduction runs on the PAPER's hardware model (A100 + 100Gb IB); the
# v5e rows show the same mechanism on the TPU target (DESIGN.md §2).
HWS = {"paperhw": A100_IB, "v5e": V5E}


def _wall_time_smoke(cfg, lina: bool, steps: int = 3) -> float:
    """Real CPU wall time of the smoke-scale train step (us)."""
    sc = cfg.smoke()
    dc = DataConfig(vocab_size=sc.vocab_size, seq_len=32, global_batch=2)
    params = lm_mod.init_params(sc, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(sc, None, opt_cfg, lina=lina, fsdp=False))
    batch = {k: jnp.asarray(v) for k, v in SyntheticLM(dc).batch(0).items()}
    step(params, opt, batch)  # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        params, opt, _ = step(params, opt, batch)
    jax.block_until_ready(opt.step)
    return (time.perf_counter() - t0) / steps * 1e6


def table1_a2a_fraction():
    """Table 1: a2a completion time and its share of step time."""
    rows = []
    for hw_name, hw in HWS.items():
        for n_exp in (4, 16):
            for lname, layers in (("12L", 12), ("24L", 24), ("36L", 36)):
                import dataclasses
                cfg = dataclasses.replace(with_experts(TRANSFORMER_XL, n_exp),
                                          n_layers=layers)
                m = step_model_for(cfg, SEQ, BATCH, n_devices=n_exp, hw=hw)
                r = simulate_step(m, "baseline")
                frac = r["a2a_time_total"] / max(r["step_time"], 1e-12)
                rows.append((f"table1/{hw_name}/txl-{lname}-{n_exp}e", 0.0,
                             f"a2a_ms={r['a2a_time_total']*1e3:.2f},"
                             f"fraction={frac:.3f}"))
    return rows


def fig10_training_speedup():
    """Figs. 10-13: step-time / a2a speedup of Lina over Baseline."""
    rows = []
    for hw_name, hw in HWS.items():
        for mname, base in PAPER_MODELS.items():
            anchor = None
            for n_exp in (2, 4, 8, 16):
                cfg = with_experts(base, n_exp)
                m = step_model_for(cfg, SEQ, BATCH, n_devices=n_exp, hw=hw)
                rb = simulate_step(m, "baseline")
                rl = simulate_step(m, "priority+partition+pipeline")
                if anchor is None and hw_name == "paperhw":
                    anchor = (_wall_time_smoke(cfg, lina=False),
                              _wall_time_smoke(cfg, lina=True))
                speed = rb["step_time"] / max(rl["step_time"], 1e-12)
                a2a_speed = (rb["bwd"]["a2a_time_total"]
                             / max(rl["bwd"]["a2a_time_total"], 1e-12))
                rows.append((f"fig10/{hw_name}/{mname}-{n_exp}e",
                             anchor[1] if anchor else 0.0,
                             f"step_speedup={speed:.2f},"
                             f"bwd_a2a_speedup={a2a_speed:.2f}"
                             + (f",cpu_baseline_us={anchor[0]:.0f}"
                                if anchor else "")))
    return rows


def fig14_design_ablation():
    """Fig. 14: incremental gains of priority / partitioning / pipelining."""
    rows = []
    for mname, base in PAPER_MODELS.items():
        for n_exp in (4, 16):
            cfg = with_experts(base, n_exp)
            m = step_model_for(cfg, SEQ, BATCH, n_devices=n_exp, hw=A100_IB)
            base_t = simulate_step(m, "baseline")["step_time"]
            parts = []
            for s in SCHEDULES[1:]:
                t = simulate_step(m, s)["step_time"]
                parts.append(f"{s.split('+')[-1]}={base_t / t:.2f}")
            rows.append((f"fig14/paperhw/{mname}-{n_exp}e", 0.0,
                         ",".join(parts)))
    return rows


def fig15_partition_size():
    """Fig. 15: step time vs micro-op partition size (10MB..200MB)."""
    rows = []
    cfg = with_experts(TRANSFORMER_XL, 16)
    m = step_model_for(cfg, SEQ, BATCH, n_devices=16, hw=A100_IB)
    for mb in (10e6, 30e6, 50e6, 100e6, 200e6):
        t = simulate_step(m, "priority+partition+pipeline",
                          partition_bytes=mb)["step_time"]
        rows.append((f"fig15/paperhw/txl-16e-{int(mb/1e6)}MB", 0.0,
                     f"step_ms={t*1e3:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# measured (not simulated) schedule ablation
# ---------------------------------------------------------------------------

MEASURED_SCHEDULES = ("baseline", "priority", "fixed", "priority+partition",
                      "priority+partition+pipeline")


# The ablation times the SMOKE config (~1MB of gradients), so the paper-
# scale 30MB default would collapse every partitioned schedule to a single
# chunk; the sweep below (the smoke-scale Fig. 15) finds the measured
# minimum and the ablation runs at that size — 256KB is only the fallback
# when the sweep is disabled.
MEASURED_PARTITION_BYTES = 256e3
PARTITION_SWEEP = (64e3, 128e3, 256e3, 512e3, 1e6)


def _measure_schedules_inprocess(schedules, steps, batch, seq, microbatches,
                                 partition_bytes=MEASURED_PARTITION_BYTES,
                                 grad_compression=None, partition_sweep=()):
    """Worker body: time each schedule's jitted train step on THIS process's
    device set (the parent forces the device count via XLA_FLAGS).

    With ``partition_sweep`` the worker first times the
    ``priority+partition`` step at each candidate micro-op size (the
    measured, smoke-scale Fig. 15) and runs the main ablation at the
    measured minimum.  Returns (rows, sweep_rows, partition_bytes)."""
    from repro.launch.mesh import mesh_context
    from repro.optim import reduce as reduce_mod

    n = jax.device_count()
    # dp first (the reduce under test runs over dp); ep>1 only when there
    # are enough devices for both axes (n>=4 -> a2a AND reduce contend)
    ep = 2 if n % 2 == 0 and n >= 4 else 1
    dp = max(n // ep, 1)
    mesh = jax.make_mesh((dp, ep), ("data", "model"))
    cfg = GPT2_MOE.smoke()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                    global_batch=batch)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt0 = init_opt_state(params, opt_cfg)
    data = {k: jnp.asarray(v) for k, v in SyntheticLM(dc).batch(0).items()}

    def time_schedule(sched, pb):
        step = jax.jit(make_train_step(
            cfg, mesh, opt_cfg, fsdp=False, microbatches=microbatches,
            schedule=sched, partition_bytes=pb,
            grad_compression=grad_compression))
        rstate = None
        if grad_compression == "int8_ef":
            rstate = reduce_mod.init_reduce_state(
                params, reduce_mod.ReduceConfig(sched,
                                                compression=grad_compression))
        args = (params, opt0, data) + ((rstate,) if rstate is not None else ())
        with mesh_context(mesh):
            r = step(*args)                        # compile + warm caches
            p, o = r[0], r[1]
            jax.block_until_ready(o.step)
            t0 = time.perf_counter()
            for _ in range(steps):
                r = step(p, o, data, *r[3:])
                p, o = r[0], r[1]
            jax.block_until_ready(o.step)
        return (time.perf_counter() - t0) / steps * 1e6

    sweep_rows = []
    sweep_times = {}
    if partition_sweep:
        for pb in partition_sweep:
            sweep_rows.append((float(pb), time_schedule("priority+partition",
                                                        pb)))
        sweep_times = dict(sweep_rows)
        partition_bytes = min(sweep_rows, key=lambda r: r[1])[0]

    # grads are params-shaped: report the micro-op count each schedule
    # actually compiled (non-partitioned schedules run one fused reduce)
    part_chunks = reduce_mod.n_chunks_for_bytes(params, partition_bytes)
    out = []
    for sched in schedules:
        n_chunks = part_chunks if "partition" in sched else 1
        # the sweep already timed priority+partition at the chosen size —
        # reuse it instead of paying another compile + timed run
        us = sweep_times.get(partition_bytes) \
            if sched == "priority+partition" else None
        if us is None:
            us = time_schedule(sched, partition_bytes)
        out.append((sched, us, dp, ep, n_chunks))
    return out, sweep_rows, partition_bytes


# ---------------------------------------------------------------------------
# measured overlap efficiency (Fig. 8b pipeline + ScMoE shortcut)
# ---------------------------------------------------------------------------

OVERLAP_VARIANTS = ("pipelined", "pipelined+grouped", "shortcut")
OVERLAP_CHUNKS = (1, 2, 4, 8)


def _measure_overlap_inprocess(variants, chunk_counts, steps, batch, seq,
                               mode="train"):
    """Worker body: time the expert-parallel MoE layer per overlap variant
    and requested chunk count on THIS process's device mesh, next to its
    own serial (pipeline-off) baseline and an a2a-only reference, and
    report the measured fraction of a2a time the pipeline hides:
    ``(serial - pipelined) / a2a``, clipped to [0, 1].

    Variants: "pipelined" (xla compute), "pipelined+grouped" (the
    re-entrant grouped_ffn Pallas kernel per landed chunk), "shortcut"
    (ScMoE dense branch under the a2a shadow).  ``mode="train"`` times
    forward+backward; ``"infer"`` forward only.  Returns rows of
    (mode, variant, requested, chosen, pipe_us, serial_us, a2a_us,
    hidden_frac) — requested vs *chosen* chunk count are both surfaced
    (resolve_chunk_count; no silent caps)."""
    import dataclasses

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import microop
    from repro.core import moe as moe_mod
    from repro.core.gating import capacity

    n = jax.device_count()
    ep = 2 if n % 2 == 0 and n >= 4 else 1
    dp = max(n // ep, 1)
    mesh = jax.make_mesh((dp, ep), ("data", "model"))
    cfg = GPT2_MOE.smoke()
    d, e, k = cfg.d_model, cfg.moe.n_experts, cfg.moe.top_k
    f = cfg.moe.d_ff or cfg.d_ff
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    params = moe_mod.init_moe_params(ks[0], d, f, e, cfg.ffn_type)
    sc_params = ((jax.random.normal(ks[1], (d, f)) * d ** -0.5),
                 (jax.random.normal(ks[2], (d, f)) * d ** -0.5),
                 (jax.random.normal(ks[3], (f, d)) * f ** -0.5))
    x = jax.random.normal(ks[4], (batch, seq, d))

    b_loc = batch // dp if batch % dp == 0 else batch
    s_loc = seq // ep if seq % ep == 0 else seq
    cap = capacity(b_loc * s_loc, e, k, cfg.moe.capacity_factor)

    def timed(fn, *args):
        out = fn(*args)                            # compile + warm caches
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(steps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / steps * 1e6

    def layer_time(moe_cfg, sc):
        def fwd(p, xx):
            out = moe_mod.moe_layer(mesh, xx, p, moe_cfg,
                                    ffn_type=cfg.ffn_type, lina=True,
                                    shortcut_params=sc)
            return (out.y.astype(jnp.float32) ** 2).sum()
        fn = jax.grad(fwd) if mode == "train" else fwd
        return timed(jax.jit(fn), params, x)

    # a2a-only reference: the layer's chunked dispatch + combine exchanges
    # with an identity expert — what the pipeline is trying to hide
    buf = jax.random.normal(key, (e, cap, d))

    def a2a_time(nc):
        def body(b):
            outs = microop.chunked_all_to_all(b, "model", nc)
            back = [microop.all_to_all_ec_inverse(o, "model", e)
                    for o in outs]
            return back[0] if len(back) == 1 else jnp.concatenate(back,
                                                                  axis=1)
        fn = shard_map(body, mesh=mesh, in_specs=(P(None, None, None),),
                       out_specs=P(None, None, None), check_rep=False)
        return timed(jax.jit(fn), buf)

    a2a_us = {nc: a2a_time(nc) for nc in chunk_counts}
    rows = []
    for variant in variants:
        backend = "pallas" if variant == "pipelined+grouped" else "xla"
        sc = sc_params if variant == "shortcut" else None
        base = dataclasses.replace(cfg.moe, compute_backend=backend)
        serial_us = layer_time(
            dataclasses.replace(base, pipeline_ffn=False), sc)
        for nc in chunk_counts:
            chosen = microop.resolve_chunk_count(cap, nc)
            pipe_us = layer_time(
                dataclasses.replace(base, n_microops=nc, pipeline_ffn=True),
                sc)
            hidden = max(0.0, min(1.0, (serial_us - pipe_us)
                                  / max(a2a_us[nc], 1e-9)))
            rows.append((mode, variant, nc, chosen, pipe_us, serial_us,
                         a2a_us[nc], hidden))
    return rows


def overlap_rows_subprocess(device_count: int = 4, steps: int = 5,
                            batch: int = 4, seq: int = 32,
                            variants=OVERLAP_VARIANTS,
                            chunk_counts=OVERLAP_CHUNKS, mode="train",
                            timeout=1800):
    """Spawn the forced-device worker for the overlap microbench only and
    return the parsed rows (shared by the infer-side benchmark)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count"
                        f"={device_count}").strip()
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(repo, "src"), repo])
    cmd = [sys.executable, "-m", "benchmarks.train_side",
           "--steps", str(steps), "--batch", str(batch), "--seq", str(seq),
           "--overlap-variants", ",".join(variants),
           "--overlap-chunks", ",".join(str(c) for c in chunk_counts),
           "--overlap-mode", mode]
    p = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                       text=True, timeout=timeout)
    if p.returncode != 0:
        raise RuntimeError(f"overlap worker failed:\n{p.stderr[-3000:]}")
    return _parse_overlap_lines(p.stdout)


def _parse_overlap_lines(stdout: str):
    rows = []
    for line in stdout.splitlines():
        if not line.startswith("OVERLAP "):
            continue
        (_, mode, variant, req, chosen, pipe_us, serial_us, a2a_us,
         hidden) = line.split()
        rows.append({"mode": mode, "variant": variant,
                     "chunks_requested": int(req),
                     "chunks_chosen": int(chosen),
                     "us_per_call": float(pipe_us),
                     "serial_us": float(serial_us),
                     "a2a_us": float(a2a_us),
                     "a2a_hidden_frac": float(hidden)})
    return rows


def measured_schedule_ablation(device_count: int = 4, steps: int = 5,
                               batch: int = 4, seq: int = 32,
                               microbatches: int = 2,
                               schedules=MEASURED_SCHEDULES,
                               partition_bytes: float = None,
                               partition_sweep=PARTITION_SWEEP,
                               grad_compression=None,
                               overlap_variants=OVERLAP_VARIANTS,
                               overlap_chunks=OVERLAP_CHUNKS,
                               json_path: str = "BENCH_schedules.json"):
    """Measured wall time of each gradient-reduction schedule through the
    real jitted train step on a ``device_count``-device CPU mesh, with the
    analytic paper-hardware step time for the same schedule alongside.

    ``partition_bytes=None`` (the default) auto-picks the micro-op size:
    the worker times ``priority+partition`` over ``partition_sweep`` (the
    measured, smoke-scale analogue of Fig. 15) and the ablation runs at the
    measured minimum; the chosen value is recorded in ``json_path`` and in
    every row.  Pass an explicit float to pin it.

    The same worker also runs the overlap-efficiency microbench
    (``_measure_overlap_inprocess``): per variant x chunk count, the
    fraction of a2a time hidden by the chunk pipeline, written into
    ``json_path`` under ``"overlap"`` with requested *and* chosen chunk
    counts as columns.  Pass ``overlap_variants=()`` to skip."""
    import json

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count"
                        f"={device_count}").strip()
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(repo, "src"), repo])
    cmd = [sys.executable, "-m", "benchmarks.train_side",
           "--schedules", ",".join(schedules), "--steps", str(steps),
           "--batch", str(batch), "--seq", str(seq),
           "--microbatches", str(microbatches)]
    if partition_bytes is None:
        cmd += ["--partition-sweep",
                ",".join(str(float(pb)) for pb in partition_sweep)]
    else:
        cmd += ["--partition-bytes", str(partition_bytes)]
    if grad_compression:
        cmd += ["--grad-compression", grad_compression]
    if overlap_variants and overlap_chunks:
        cmd += ["--overlap-variants", ",".join(overlap_variants),
                "--overlap-chunks", ",".join(str(c) for c in overlap_chunks),
                "--overlap-mode", "train"]
    p = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                       text=True, timeout=1800)
    if p.returncode != 0:
        raise RuntimeError(f"measure worker failed:\n{p.stderr[-3000:]}")
    measured = {}
    notes = {}
    sweep = []
    chosen = partition_bytes or MEASURED_PARTITION_BYTES
    for line in p.stdout.splitlines():
        if line.startswith("MEASURED "):
            _, sched, us, dp, ep, nchunks = line.split()
            measured[sched] = float(us)
            notes[sched] = f"mesh={dp}x{ep},n_chunks={nchunks}"
        elif line.startswith("SWEEP "):
            _, pb, us = line.split()
            sweep.append((float(pb), float(us)))
        elif line.startswith("CHOSEN "):
            chosen = float(line.split()[1])
    overlap = _parse_overlap_lines(p.stdout)
    sim = step_model_for(with_experts(GPT2_MOE, 16), SEQ, BATCH,
                         n_devices=16, hw=A100_IB)
    rows = []
    jrows = []
    comp_note = f",compression={grad_compression}" if grad_compression else ""
    for pb, us in sweep:
        rows.append((f"schedules/partition-sweep/{int(pb/1e3)}KB", us,
                     f"chosen={pb == chosen}"))
    for sched in schedules:
        sim_ms = simulate_step(sim, sched)["step_time"] * 1e3
        rows.append((f"schedules/measured/gpt2-{sched}", measured[sched],
                     f"{notes[sched]},microbatches={microbatches}{comp_note},"
                     f"partition_bytes={chosen:.0f},"
                     f"sim_paperhw_step_ms={sim_ms:.3f}"))
        jrows.append({"schedule": sched, "us_per_step": measured[sched],
                      "notes": notes[sched],
                      "sim_paperhw_step_ms": sim_ms})
    if "baseline" in measured and "priority+partition+pipeline" in measured:
        base = measured["baseline"]
        lina = measured["priority+partition+pipeline"]
        rows.append(("schedules/measured/speedup", 0.0,
                     f"baseline_us={base:.0f},lina_us={lina:.0f},"
                     f"measured_speedup={base / max(lina, 1e-9):.3f}"))
    for o in overlap:
        rows.append((f"schedules/overlap/{o['variant']}"
                     f"-c{o['chunks_requested']}", o["us_per_call"],
                     f"chunks_requested={o['chunks_requested']},"
                     f"chunks_chosen={o['chunks_chosen']},"
                     f"serial_us={o['serial_us']:.1f},"
                     f"a2a_us={o['a2a_us']:.1f},"
                     f"a2a_hidden_frac={o['a2a_hidden_frac']:.3f}"))
    if not os.path.isabs(json_path):
        json_path = os.path.join(repo, json_path)
    with open(json_path, "w") as fh:
        json.dump({
            "partition_bytes": chosen,
            "partition_bytes_source": "measured-sweep-min" if sweep
            else "pinned",
            "partition_sweep": [{"bytes": pb, "us_per_step": us}
                                for pb, us in sweep],
            "microbatches": microbatches,
            "grad_compression": grad_compression,
            "rows": jrows,
            "overlap": overlap,
        }, fh, indent=1)
    return rows


def _worker_main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedules", default="",
                    help="comma-separated schedule names; empty skips the "
                         "schedule timing (overlap-only worker run)")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--partition-bytes", type=float,
                    default=MEASURED_PARTITION_BYTES)
    ap.add_argument("--partition-sweep", default="",
                    help="comma-separated micro-op sizes; when given, the "
                         "measured minimum overrides --partition-bytes")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--overlap-variants", default="",
                    help="comma-separated overlap variants "
                         "(pipelined|pipelined+grouped|shortcut); empty "
                         "skips the overlap microbench")
    ap.add_argument("--overlap-chunks", default="",
                    help="comma-separated requested chunk counts")
    ap.add_argument("--overlap-mode", default="train",
                    choices=["train", "infer"],
                    help="train times forward+backward, infer forward only")
    args = ap.parse_args(argv)
    if args.schedules:
        sweep = tuple(float(s) for s in args.partition_sweep.split(",")) \
            if args.partition_sweep else ()
        rows, sweep_rows, chosen = _measure_schedules_inprocess(
            args.schedules.split(","), args.steps, args.batch, args.seq,
            args.microbatches, partition_bytes=args.partition_bytes,
            grad_compression=args.grad_compression, partition_sweep=sweep)
        for pb, us in sweep_rows:
            print(f"SWEEP {pb:.0f} {us:.1f}", flush=True)
        print(f"CHOSEN {chosen:.0f}", flush=True)
        for sched, us, dp, ep, n_chunks in rows:
            print(f"MEASURED {sched} {us:.1f} {dp} {ep} {n_chunks}",
                  flush=True)
    if args.overlap_variants and args.overlap_chunks:
        orows = _measure_overlap_inprocess(
            args.overlap_variants.split(","),
            tuple(int(c) for c in args.overlap_chunks.split(",")),
            args.steps, args.batch, args.seq, mode=args.overlap_mode)
        for (mode, variant, req, chosen_c, pipe_us, serial_us, a2a_us,
             hidden) in orows:
            print(f"OVERLAP {mode} {variant} {req} {chosen_c} "
                  f"{pipe_us:.1f} {serial_us:.1f} {a2a_us:.1f} "
                  f"{hidden:.4f}", flush=True)


def table3_packing():
    """Table 3: pipeline efficiency without / with expert packing."""
    rows = []
    for hw_name, hw in HWS.items():
        for mname, base in PAPER_MODELS.items():
            cfg = with_experts(base, 16)
            tokens = BATCH * SEQ // 16 // max(cfg.moe.n_microops, 1)
            no_pack = choose_packing(tokens, cfg.d_model,
                                     cfg.moe.d_ff or cfg.d_ff, 16, 16,
                                     ffn_mult=2, max_pack=1, hw=hw)
            packed = choose_packing(tokens, cfg.d_model,
                                    cfg.moe.d_ff or cfg.d_ff, 16, 16,
                                    ffn_mult=2, max_pack=8, hw=hw)
            rows.append((f"table3/{hw_name}/{mname}-16e", 0.0,
                         f"eff_no_pack={no_pack.pipeline_efficiency:.2f},"
                         f"eff_packed={packed.pipeline_efficiency:.2f},"
                         f"experts_per_device={packed.experts_per_device}"))
    return rows


if __name__ == "__main__":
    _worker_main()
