"""Training-side benchmarks: Table 1, Figs. 10-15, Table 3, and the
MEASURED schedule ablation (``schedules``).

Each function returns rows of (name, us_per_call, derived).  ``us_per_call``
is a real CPU wall-time of the corresponding smoke-scale jitted step (the
anchor proving the code path runs); ``derived`` carries the v5e-modelled
quantity the paper table reports.

``measured_schedule_ablation`` is different in kind: it runs every Lina §4
gradient-reduction schedule through the REAL jitted train step on a forced
multi-device CPU mesh (``XLA_FLAGS=--xla_force_host_platform_device_count``,
in a subprocess so the parent's jax stays single-device per the dry-run
rules) and reports measured wall time next to the analytic
``simulate_step`` number for the same schedule.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.commmodel import (MoEStepModel, simulate_backward,
                                  simulate_step, step_model_for)
from repro.configs import TRANSFORMER_XL, GPT2_MOE, BERT2GPT2, with_experts
from repro.configs.base import V5E, A100_IB
from repro.core.packing import choose_packing
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, init_opt_state

PAPER_MODELS = {"transformer-xl": TRANSFORMER_XL, "gpt2": GPT2_MOE,
                "bert2gpt2": BERT2GPT2}
SEQ, BATCH = 1024, 64           # paper-scale shapes for the model
SCHEDULES = ["baseline", "priority", "priority+partition",
             "priority+partition+pipeline", "fixed"]
# Reproduction runs on the PAPER's hardware model (A100 + 100Gb IB); the
# v5e rows show the same mechanism on the TPU target (DESIGN.md §2).
HWS = {"paperhw": A100_IB, "v5e": V5E}


def _wall_time_smoke(cfg, lina: bool, steps: int = 3) -> float:
    """Real CPU wall time of the smoke-scale train step (us)."""
    sc = cfg.smoke()
    dc = DataConfig(vocab_size=sc.vocab_size, seq_len=32, global_batch=2)
    params = lm_mod.init_params(sc, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(sc, None, opt_cfg, lina=lina, fsdp=False))
    batch = {k: jnp.asarray(v) for k, v in SyntheticLM(dc).batch(0).items()}
    step(params, opt, batch)  # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        params, opt, _ = step(params, opt, batch)
    jax.block_until_ready(opt.step)
    return (time.perf_counter() - t0) / steps * 1e6


def table1_a2a_fraction():
    """Table 1: a2a completion time and its share of step time."""
    rows = []
    for hw_name, hw in HWS.items():
        for n_exp in (4, 16):
            for lname, layers in (("12L", 12), ("24L", 24), ("36L", 36)):
                import dataclasses
                cfg = dataclasses.replace(with_experts(TRANSFORMER_XL, n_exp),
                                          n_layers=layers)
                m = step_model_for(cfg, SEQ, BATCH, n_devices=n_exp, hw=hw)
                r = simulate_step(m, "baseline")
                frac = r["a2a_time_total"] / max(r["step_time"], 1e-12)
                rows.append((f"table1/{hw_name}/txl-{lname}-{n_exp}e", 0.0,
                             f"a2a_ms={r['a2a_time_total']*1e3:.2f},"
                             f"fraction={frac:.3f}"))
    return rows


def fig10_training_speedup():
    """Figs. 10-13: step-time / a2a speedup of Lina over Baseline."""
    rows = []
    for hw_name, hw in HWS.items():
        for mname, base in PAPER_MODELS.items():
            anchor = None
            for n_exp in (2, 4, 8, 16):
                cfg = with_experts(base, n_exp)
                m = step_model_for(cfg, SEQ, BATCH, n_devices=n_exp, hw=hw)
                rb = simulate_step(m, "baseline")
                rl = simulate_step(m, "priority+partition+pipeline")
                if anchor is None and hw_name == "paperhw":
                    anchor = (_wall_time_smoke(cfg, lina=False),
                              _wall_time_smoke(cfg, lina=True))
                speed = rb["step_time"] / max(rl["step_time"], 1e-12)
                a2a_speed = (rb["bwd"]["a2a_time_total"]
                             / max(rl["bwd"]["a2a_time_total"], 1e-12))
                rows.append((f"fig10/{hw_name}/{mname}-{n_exp}e",
                             anchor[1] if anchor else 0.0,
                             f"step_speedup={speed:.2f},"
                             f"bwd_a2a_speedup={a2a_speed:.2f}"
                             + (f",cpu_baseline_us={anchor[0]:.0f}"
                                if anchor else "")))
    return rows


def fig14_design_ablation():
    """Fig. 14: incremental gains of priority / partitioning / pipelining."""
    rows = []
    for mname, base in PAPER_MODELS.items():
        for n_exp in (4, 16):
            cfg = with_experts(base, n_exp)
            m = step_model_for(cfg, SEQ, BATCH, n_devices=n_exp, hw=A100_IB)
            base_t = simulate_step(m, "baseline")["step_time"]
            parts = []
            for s in SCHEDULES[1:]:
                t = simulate_step(m, s)["step_time"]
                parts.append(f"{s.split('+')[-1]}={base_t / t:.2f}")
            rows.append((f"fig14/paperhw/{mname}-{n_exp}e", 0.0,
                         ",".join(parts)))
    return rows


def fig15_partition_size():
    """Fig. 15: step time vs micro-op partition size (10MB..200MB)."""
    rows = []
    cfg = with_experts(TRANSFORMER_XL, 16)
    m = step_model_for(cfg, SEQ, BATCH, n_devices=16, hw=A100_IB)
    for mb in (10e6, 30e6, 50e6, 100e6, 200e6):
        t = simulate_step(m, "priority+partition+pipeline",
                          partition_bytes=mb)["step_time"]
        rows.append((f"fig15/paperhw/txl-16e-{int(mb/1e6)}MB", 0.0,
                     f"step_ms={t*1e3:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# measured (not simulated) schedule ablation
# ---------------------------------------------------------------------------

MEASURED_SCHEDULES = ("baseline", "priority", "fixed", "priority+partition",
                      "priority+partition+pipeline")


# The ablation times the SMOKE config (~1MB of gradients), so the paper-
# scale 30MB default would collapse every partitioned schedule to a single
# chunk; the sweep below (the smoke-scale Fig. 15) finds the measured
# minimum and the ablation runs at that size — 256KB is only the fallback
# when the sweep is disabled.
MEASURED_PARTITION_BYTES = 256e3
PARTITION_SWEEP = (64e3, 128e3, 256e3, 512e3, 1e6)


def _measure_schedules_inprocess(schedules, steps, batch, seq, microbatches,
                                 partition_bytes=MEASURED_PARTITION_BYTES,
                                 grad_compression=None, partition_sweep=()):
    """Worker body: time each schedule's jitted train step on THIS process's
    device set (the parent forces the device count via XLA_FLAGS).

    With ``partition_sweep`` the worker first times the
    ``priority+partition`` step at each candidate micro-op size (the
    measured, smoke-scale Fig. 15) and runs the main ablation at the
    measured minimum.  Returns (rows, sweep_rows, partition_bytes)."""
    from repro.launch.mesh import mesh_context
    from repro.optim import reduce as reduce_mod

    n = jax.device_count()
    # dp first (the reduce under test runs over dp); ep>1 only when there
    # are enough devices for both axes (n>=4 -> a2a AND reduce contend)
    ep = 2 if n % 2 == 0 and n >= 4 else 1
    dp = max(n // ep, 1)
    mesh = jax.make_mesh((dp, ep), ("data", "model"))
    cfg = GPT2_MOE.smoke()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                    global_batch=batch)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt0 = init_opt_state(params, opt_cfg)
    data = {k: jnp.asarray(v) for k, v in SyntheticLM(dc).batch(0).items()}

    def time_schedule(sched, pb):
        step = jax.jit(make_train_step(
            cfg, mesh, opt_cfg, fsdp=False, microbatches=microbatches,
            schedule=sched, partition_bytes=pb,
            grad_compression=grad_compression))
        rstate = None
        if grad_compression == "int8_ef":
            rstate = reduce_mod.init_reduce_state(
                params, reduce_mod.ReduceConfig(sched,
                                                compression=grad_compression))
        args = (params, opt0, data) + ((rstate,) if rstate is not None else ())
        with mesh_context(mesh):
            r = step(*args)                        # compile + warm caches
            p, o = r[0], r[1]
            jax.block_until_ready(o.step)
            t0 = time.perf_counter()
            for _ in range(steps):
                r = step(p, o, data, *r[3:])
                p, o = r[0], r[1]
            jax.block_until_ready(o.step)
        return (time.perf_counter() - t0) / steps * 1e6

    sweep_rows = []
    sweep_times = {}
    if partition_sweep:
        for pb in partition_sweep:
            sweep_rows.append((float(pb), time_schedule("priority+partition",
                                                        pb)))
        sweep_times = dict(sweep_rows)
        partition_bytes = min(sweep_rows, key=lambda r: r[1])[0]

    # grads are params-shaped: report the micro-op count each schedule
    # actually compiled (non-partitioned schedules run one fused reduce)
    part_chunks = reduce_mod.n_chunks_for_bytes(params, partition_bytes)
    out = []
    for sched in schedules:
        n_chunks = part_chunks if "partition" in sched else 1
        # the sweep already timed priority+partition at the chosen size —
        # reuse it instead of paying another compile + timed run
        us = sweep_times.get(partition_bytes) \
            if sched == "priority+partition" else None
        if us is None:
            us = time_schedule(sched, partition_bytes)
        out.append((sched, us, dp, ep, n_chunks))
    return out, sweep_rows, partition_bytes


def measured_schedule_ablation(device_count: int = 4, steps: int = 5,
                               batch: int = 4, seq: int = 32,
                               microbatches: int = 2,
                               schedules=MEASURED_SCHEDULES,
                               partition_bytes: float = None,
                               partition_sweep=PARTITION_SWEEP,
                               grad_compression=None,
                               json_path: str = "BENCH_schedules.json"):
    """Measured wall time of each gradient-reduction schedule through the
    real jitted train step on a ``device_count``-device CPU mesh, with the
    analytic paper-hardware step time for the same schedule alongside.

    ``partition_bytes=None`` (the default) auto-picks the micro-op size:
    the worker times ``priority+partition`` over ``partition_sweep`` (the
    measured, smoke-scale analogue of Fig. 15) and the ablation runs at the
    measured minimum; the chosen value is recorded in ``json_path`` and in
    every row.  Pass an explicit float to pin it."""
    import json

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count"
                        f"={device_count}").strip()
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(repo, "src"), repo])
    cmd = [sys.executable, "-m", "benchmarks.train_side",
           "--schedules", ",".join(schedules), "--steps", str(steps),
           "--batch", str(batch), "--seq", str(seq),
           "--microbatches", str(microbatches)]
    if partition_bytes is None:
        cmd += ["--partition-sweep",
                ",".join(str(float(pb)) for pb in partition_sweep)]
    else:
        cmd += ["--partition-bytes", str(partition_bytes)]
    if grad_compression:
        cmd += ["--grad-compression", grad_compression]
    p = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                       text=True, timeout=1800)
    if p.returncode != 0:
        raise RuntimeError(f"measure worker failed:\n{p.stderr[-3000:]}")
    measured = {}
    notes = {}
    sweep = []
    chosen = partition_bytes or MEASURED_PARTITION_BYTES
    for line in p.stdout.splitlines():
        if line.startswith("MEASURED "):
            _, sched, us, dp, ep, nchunks = line.split()
            measured[sched] = float(us)
            notes[sched] = f"mesh={dp}x{ep},n_chunks={nchunks}"
        elif line.startswith("SWEEP "):
            _, pb, us = line.split()
            sweep.append((float(pb), float(us)))
        elif line.startswith("CHOSEN "):
            chosen = float(line.split()[1])
    sim = step_model_for(with_experts(GPT2_MOE, 16), SEQ, BATCH,
                         n_devices=16, hw=A100_IB)
    rows = []
    jrows = []
    comp_note = f",compression={grad_compression}" if grad_compression else ""
    for pb, us in sweep:
        rows.append((f"schedules/partition-sweep/{int(pb/1e3)}KB", us,
                     f"chosen={pb == chosen}"))
    for sched in schedules:
        sim_ms = simulate_step(sim, sched)["step_time"] * 1e3
        rows.append((f"schedules/measured/gpt2-{sched}", measured[sched],
                     f"{notes[sched]},microbatches={microbatches}{comp_note},"
                     f"partition_bytes={chosen:.0f},"
                     f"sim_paperhw_step_ms={sim_ms:.3f}"))
        jrows.append({"schedule": sched, "us_per_step": measured[sched],
                      "notes": notes[sched],
                      "sim_paperhw_step_ms": sim_ms})
    if "baseline" in measured and "priority+partition+pipeline" in measured:
        base = measured["baseline"]
        lina = measured["priority+partition+pipeline"]
        rows.append(("schedules/measured/speedup", 0.0,
                     f"baseline_us={base:.0f},lina_us={lina:.0f},"
                     f"measured_speedup={base / max(lina, 1e-9):.3f}"))
    if not os.path.isabs(json_path):
        json_path = os.path.join(repo, json_path)
    with open(json_path, "w") as fh:
        json.dump({
            "partition_bytes": chosen,
            "partition_bytes_source": "measured-sweep-min" if sweep
            else "pinned",
            "partition_sweep": [{"bytes": pb, "us_per_step": us}
                                for pb, us in sweep],
            "microbatches": microbatches,
            "grad_compression": grad_compression,
            "rows": jrows,
        }, fh, indent=1)
    return rows


def _worker_main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedules", required=True)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--partition-bytes", type=float,
                    default=MEASURED_PARTITION_BYTES)
    ap.add_argument("--partition-sweep", default="",
                    help="comma-separated micro-op sizes; when given, the "
                         "measured minimum overrides --partition-bytes")
    ap.add_argument("--grad-compression", default=None)
    args = ap.parse_args(argv)
    sweep = tuple(float(s) for s in args.partition_sweep.split(",")) \
        if args.partition_sweep else ()
    rows, sweep_rows, chosen = _measure_schedules_inprocess(
        args.schedules.split(","), args.steps, args.batch, args.seq,
        args.microbatches, partition_bytes=args.partition_bytes,
        grad_compression=args.grad_compression, partition_sweep=sweep)
    for pb, us in sweep_rows:
        print(f"SWEEP {pb:.0f} {us:.1f}", flush=True)
    print(f"CHOSEN {chosen:.0f}", flush=True)
    for sched, us, dp, ep, n_chunks in rows:
        print(f"MEASURED {sched} {us:.1f} {dp} {ep} {n_chunks}", flush=True)


def table3_packing():
    """Table 3: pipeline efficiency without / with expert packing."""
    rows = []
    for hw_name, hw in HWS.items():
        for mname, base in PAPER_MODELS.items():
            cfg = with_experts(base, 16)
            tokens = BATCH * SEQ // 16 // max(cfg.moe.n_microops, 1)
            no_pack = choose_packing(tokens, cfg.d_model,
                                     cfg.moe.d_ff or cfg.d_ff, 16, 16,
                                     ffn_mult=2, max_pack=1, hw=hw)
            packed = choose_packing(tokens, cfg.d_model,
                                    cfg.moe.d_ff or cfg.d_ff, 16, 16,
                                    ffn_mult=2, max_pack=8, hw=hw)
            rows.append((f"table3/{hw_name}/{mname}-16e", 0.0,
                         f"eff_no_pack={no_pack.pipeline_efficiency:.2f},"
                         f"eff_packed={packed.pipeline_efficiency:.2f},"
                         f"experts_per_device={packed.experts_per_device}"))
    return rows


if __name__ == "__main__":
    _worker_main()
