"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig16]

Prints ``name,us_per_call,derived`` CSV.  ``us_per_call`` is a measured CPU
wall time of the corresponding smoke-scale code path (0.0 for pure-model
rows); ``derived`` is the v5e-modelled quantity the paper reports (see
benchmarks/commmodel.py and benchmarks/inference_model.py for methodology).
"""
from __future__ import annotations

import argparse
import sys
import time


def all_benchmarks():
    from benchmarks import train_side, infer_side
    return [
        ("table1", train_side.table1_a2a_fraction),
        ("fig10", train_side.fig10_training_speedup),
        ("fig14", train_side.fig14_design_ablation),
        ("fig15", train_side.fig15_partition_size),
        ("table3", train_side.table3_packing),
        ("fig16", infer_side.fig16_inference_time),
        ("table5", infer_side.table5_path_length),
        ("fig19", infer_side.fig19_estimation_accuracy),
        ("traffic", infer_side.traffic_skewed_bursty),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    for name, fn in all_benchmarks():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001 — a failing table must not
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            continue
        for rname, us, derived in rows:
            print(f'{rname},{us:.1f},"{derived}"', flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
