"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig16]

Prints ``name,us_per_call,derived`` CSV.  ``us_per_call`` is a measured CPU
wall time of the corresponding smoke-scale code path (0.0 for pure-model
rows); ``derived`` is the v5e-modelled quantity the paper reports (see
benchmarks/commmodel.py and benchmarks/inference_model.py for methodology).
"""
from __future__ import annotations

import argparse
import sys
import time


# per-table kwargs for --smoke: a CI-sized run of the same code path.
# Tables without an entry take no size kwargs (the train-side tables are
# already smoke-scale); --smoke prints a note when it runs one unreduced.
SMOKE_KWARGS = {
    "schedules": dict(device_count=2, steps=2, batch=2, seq=16,
                      microbatches=2,
                      schedules=("baseline", "fixed",
                                 "priority+partition+pipeline"),
                      partition_sweep=(128e3, 256e3),
                      overlap_variants=("pipelined", "pipelined+grouped",
                                        "shortcut"),
                      overlap_chunks=(2,),
                      json_path="BENCH_schedules.smoke.json"),
    "overlap-infer": dict(device_count=2, steps=2, batch=2, seq=16,
                          chunk_counts=(2,)),
    "fig16": dict(batches=2, seq=32),
    "table5": dict(batches=2, seq=32),
    "fig19": dict(batches=2, seq=32),
    "traffic": dict(n_requests=6, seq=16, rate_hz=50.0, profile_batches=2,
                    max_new_tokens=4, json_path="BENCH_traffic.smoke.json"),
    # smoke rows go to a separate (gitignored) file so CI-sized runs never
    # clobber the committed full-run BENCH_kernels.json trajectory
    "kernels": dict(models=("gpt2",), tokens_per_expert=8, iters=1, scale=8,
                    json_path="BENCH_kernels.smoke.json"),
    "autoscale": dict(n_requests=10, seq=12, rate_hz=40.0,
                      max_new_tokens=3, profile_batches=2,
                      traces=("drift", "flash"), warm=False,
                      json_path="BENCH_autoscale.smoke.json"),
    "resilience": dict(n_requests=20, seq=12, rate_hz=12.0,
                       max_new_tokens=3, profile_batches=2,
                       traces=("drift",), burst=24, max_queue=12,
                       json_path="BENCH_resilience.smoke.json"),
}


def all_benchmarks():
    from benchmarks import (train_side, infer_side, kernel_side,
                            autoscale_side, resilience_side)
    return [
        ("kernels", kernel_side.kernels_benchmark),
        ("autoscale", autoscale_side.autoscale_benchmark),
        ("resilience", resilience_side.resilience_benchmark),
        ("table1", train_side.table1_a2a_fraction),
        ("fig10", train_side.fig10_training_speedup),
        ("fig14", train_side.fig14_design_ablation),
        ("fig15", train_side.fig15_partition_size),
        ("table3", train_side.table3_packing),
        ("schedules", train_side.measured_schedule_ablation),
        ("overlap-infer", infer_side.overlap_efficiency_infer),
        ("fig16", infer_side.fig16_inference_time),
        ("table5", infer_side.table5_path_length),
        ("fig19", infer_side.fig19_estimation_accuracy),
        ("traffic", infer_side.traffic_skewed_bursty),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (reduced request counts / seq lens)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any benchmark errors (CI gating)")
    args = ap.parse_args(argv)

    errors = 0
    ran = 0
    print("name,us_per_call,derived")
    for name, fn in all_benchmarks():
        if args.only and args.only != name:
            continue
        ran += 1
        kwargs = SMOKE_KWARGS.get(name, {}) if args.smoke else {}
        if args.smoke and name not in SMOKE_KWARGS:
            print(f"# {name}: no smoke config, running at full size",
                  file=sys.stderr)
        t0 = time.time()
        try:
            rows = fn(**kwargs)
        except Exception as e:  # noqa: BLE001 — a failing table must not
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            errors += 1
            continue
        for rname, us, derived in rows:
            print(f'{rname},{us:.1f},"{derived}"', flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if args.only and not ran:
        sys.exit(f"no benchmark named {args.only!r}")
    if args.strict and errors:
        sys.exit(f"{errors} benchmark(s) errored")


if __name__ == "__main__":
    main()
