"""Kernel-side benchmark: pallas vs oracle timings for the MoE hot-path
kernels — fused gating, fused dispatch/combine, grouped expert FFN — across
the paper model shapes, plus the full MoE layer fwd+bwd on both compute
backends.

Every row is a REAL wall-time of the jitted op on this host.  On CPU the
pallas rows run the kernels in interpret mode (Python-per-grid-step), so
they are a correctness anchor and a baseline for the perf trajectory, not a
speedup claim — the ``pallas_mode`` field in the JSON says which regime a
row was measured in.  On a TPU host the same harness emits the native
numbers this PR's trajectory is meant to be beaten on.

Besides the CSV rows (``benchmarks/run.py --only kernels``), the run emits
machine-readable ``BENCH_kernels.json`` at the repo root: a list of row
dicts ``{bench, model, backend, shape, scale, us_per_call, platform,
pallas_mode}`` that later PRs append to / compare against.  The
checked-in copy is a FULL run; ``--smoke`` writes to the gitignored
``BENCH_kernels.smoke.json`` instead (the file CI uploads), so the
measured-trajectory artifact is never clobbered by CI-sized runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import BERT2GPT2, BERT_LARGE, GPT2_MOE, TRANSFORMER_XL
from repro.core import dispatch as D
from repro.core import init_moe_params, moe_layer
from repro.core.gating import capacity, top_k_gating
from repro.kernels import ops as K

PAPER_MODELS = {"transformer-xl": TRANSFORMER_XL, "gpt2": GPT2_MOE,
                "bert2gpt2": BERT2GPT2, "bert-large": BERT_LARGE}
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_kernels.json")


def _time_us(fn, *args, iters: int) -> float:
    fn = jax.jit(fn)
    jax.block_until_ready(fn(*args))          # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _pallas_mode() -> str:
    return "native" if K.on_tpu() else "interpret"


def kernels_benchmark(models=tuple(PAPER_MODELS), tokens_per_expert: int = 16,
                      iters: int = 2, scale: int | None = None,
                      json_path: str = JSON_PATH):
    """Per paper model: gating / dispatch+combine / grouped-FFN pallas-vs-
    oracle and a full-layer fwd+bwd xla-vs-pallas.

    ``scale`` divides the model widths.  The default is platform-aware:
    full width on TPU (kernels compile natively; these are the rows that
    count), 1/4 width on CPU, where the interpret-mode grouped GEMMs run
    the kernel body per grid step in Python and full width would take an
    hour per model.  The chosen widths and scale land in every JSON row.
    """
    if scale is None:
        scale = 1 if K.on_tpu() else 4
    if not os.path.isabs(json_path):
        json_path = os.path.join(REPO_ROOT, json_path)
    rows, jrows = [], []

    def record(bench, model, backend, shape, us, ref_us=None):
        derived = ",".join(f"{k}={v}" for k, v in shape.items())
        if ref_us is not None:
            derived += f",oracle_ratio={us / max(ref_us, 1e-9):.2f}"
        rows.append((f"kernels/{model}/{bench}/{backend}", us, derived))
        jrows.append({"bench": bench, "model": model, "backend": backend,
                      "shape": shape, "scale": scale,
                      "us_per_call": round(us, 1),
                      "platform": jax.default_backend(),
                      "pallas_mode": _pallas_mode()})

    for name in models:
        cfg = PAPER_MODELS[name]
        e = cfg.moe.n_experts
        d = max(128, cfg.d_model // scale)
        f = max(128, (cfg.moe.d_ff or cfg.d_ff) // scale)
        k = cfg.moe.top_k
        t = e * tokens_per_expert
        key = jax.random.split(jax.random.PRNGKey(0), 6)

        # --- fused gating (router matmul + softmax + top-k) ----------------
        x = jax.random.normal(key[0], (t, d)) * 0.3
        router = jax.random.normal(key[1], (d, e)) * (d ** -0.5)
        shape = {"T": t, "D": d, "E": e, "k": k}
        ref_us = _time_us(lambda a, b: K.topk_gating_op(a, b, k,
                                                        use_pallas=False),
                          x, router, iters=iters)
        pal_us = _time_us(lambda a, b: K.topk_gating_op(a, b, k,
                                                        use_pallas=True),
                          x, router, iters=iters)
        record("gating", name, "oracle", shape, ref_us)
        record("gating", name, "pallas", shape, pal_us, ref_us)

        # --- dispatch + combine --------------------------------------------
        cap = capacity(t, e, k, cfg.moe.capacity_factor)
        g = top_k_gating(x @ router, k, cap)
        buf_shape = {"T": t, "E": e, "C": cap, "D": d}

        def roundtrip(backend):
            disp, comb = D.get_backend(backend)

            def fn(x, g):
                buf = disp(x, g, e, cap)
                return comb(buf, g, e, cap)
            return fn

        ref_us = _time_us(roundtrip("einsum"), x, g, iters=iters)
        pal_us = _time_us(roundtrip("pallas"), x, g, iters=iters)
        record("dispatch_combine", name, "oracle", buf_shape, ref_us)
        record("dispatch_combine", name, "pallas", buf_shape, pal_us, ref_us)

        # --- weighted replica routing (fused positions + bin split) --------
        # the serving-side §5 split: priority positions + weighted replica
        # bins, xla-ref vs fused kernels on the gating output above
        r_w = 4
        slot_cap = max(8, -(-cap // r_w))
        cumw = jnp.cumsum(jnp.full((e, r_w), slot_cap, jnp.int32),
                          axis=1).astype(jnp.int32)
        slot_of = jnp.arange(e * r_w, dtype=jnp.int32).reshape(e, r_w)
        route_shape = {"T": t, "E": e, "k": k, "R": r_w}

        def route(use):
            def fn(ix):
                pos = K.topk_positions_op(ix, e, use_pallas=use)
                return K.weighted_route_op(ix, pos, cumw, slot_of, slot_cap,
                                           use_pallas=use)
            return fn

        ref_us = _time_us(route(False), g.expert_idx, iters=iters)
        pal_us = _time_us(route(True), g.expert_idx, iters=iters)
        record("routing", name, "oracle", route_shape, ref_us)
        record("routing", name, "pallas", route_shape, pal_us, ref_us)

        # --- grouped expert FFN --------------------------------------------
        xg = jax.random.normal(key[2], (e, tokens_per_expert, d)) * 0.3
        wi = jax.random.normal(key[3], (e, d, f)) * 0.05
        wu = jax.random.normal(key[4], (e, d, f)) * 0.05 \
            if cfg.ffn_type == "swiglu" else None
        wo = jax.random.normal(key[5], (e, f, d)) * 0.05
        ffn_shape = {"E": e, "T": tokens_per_expert, "D": d, "F": f,
                     "ffn": cfg.ffn_type}
        ref_us = _time_us(
            lambda a, b, c_, d_: K.grouped_ffn_op(a, b, c_, d_, cfg.ffn_type,
                                                  use_pallas=False),
            xg, wi, wu, wo, iters=iters)
        pal_us = _time_us(
            lambda a, b, c_, d_: K.grouped_ffn_op(a, b, c_, d_, cfg.ffn_type,
                                                  use_pallas=True),
            xg, wi, wu, wo, iters=iters)
        record("grouped_ffn", name, "oracle", ffn_shape, ref_us)
        record("grouped_ffn", name, "pallas", ffn_shape, pal_us, ref_us)

        # --- full MoE layer fwd+bwd on both compute backends ---------------
        layer_shape = {"B": 4, "S": tokens_per_expert * e // 4, "D": d,
                       "F": f, "E": e, "k": k}
        params = init_moe_params(jax.random.PRNGKey(1), d, f, e,
                                 cfg.ffn_type)
        xl = jax.random.normal(key[0], (4, layer_shape["S"], d)) * 0.3

        def fwdbwd(backend, dispatch_backend):
            mcfg = dataclasses.replace(cfg.moe, d_ff=f,
                                       compute_backend=backend)

            def loss(x, p):
                out = moe_layer(None, x, p, mcfg, ffn_type=cfg.ffn_type,
                                dispatch_backend=dispatch_backend)
                return (out.y ** 2).sum() + out.aux_loss
            return jax.grad(loss, argnums=(0, 1))

        ref_us = _time_us(fwdbwd("xla", "scatter"), xl, params, iters=iters)
        pal_us = _time_us(fwdbwd("pallas", "pallas"), xl, params,
                          iters=iters)
        record("layer_fwdbwd", name, "xla+scatter", layer_shape, ref_us)
        record("layer_fwdbwd", name, "pallas", layer_shape, pal_us, ref_us)

    # every row carries the analyzer's static VMEM estimate vs the per-core
    # budget, so measured timings and the pass-1 contract stay in one file
    from repro.analysis.kernels import annotate_bench_rows
    annotate_bench_rows(jrows)
    with open(json_path, "w") as fh:
        json.dump(jrows, fh, indent=1)
    rows.append(("kernels/json", 0.0, json_path))
    return rows


if __name__ == "__main__":
    for r in kernels_benchmark():
        print(r)
