"""Cluster timing model for the paper's training-side experiments.

This container cannot measure TPU wall time, so the training benchmarks
reproduce the paper's *mechanism* with an event-driven two-resource model
(one compute stream, one network link per device — the same abstraction as
paper Figs. 7/8) driven by byte/FLOP counts from the model configs and the
v5e constants.  Schedules:

  baseline             allreduce launches when ready; overlapping transfers
                       FAIR-SHARE the link (paper Fig. 5/7a)
  priority             whole-tensor ops; a2a never shares, but cannot
                       preempt an in-flight allreduce (Fig. 7b)
  +partition           allreduce split into uniform micro-ops that yield at
                       chunk boundaries (Fig. 8a)
  +partition+pipeline  a2a also chunked; expert FFN overlaps the a2a
                       micro-ops (Fig. 8b)
  fixed                allreduce deferred to after each MoE layer's second
                       a2a, unpartitioned (Fig. 7c)

The same model yields per-layer a2a times for inference (Fig. 16-18) where
the per-device token load comes from the placement plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

from repro.configs.base import HardwareConfig, V5E


@dataclass
class MoEStepModel:
    """Byte/FLOP counts for ONE training step of an MoE model."""
    n_moe_layers: int
    a2a_bytes: float          # one a2a op, per device (dispatch or combine)
    ffn_flops: float          # expert FFN per device per layer (one pass)
    attn_flops: float         # non-MoE backward compute per layer per device
    grad_bytes: float         # DP-allreduce bytes per layer (non-expert)
    embed_grad_bytes: float = 0.0   # embedding gradient (one big bucket,
    #                                 ready at the very end of backward)
    bucket_layers: int = 4    # DDP-style fusion: layers per allreduce bucket
    hw: HardwareConfig = V5E

    @property
    def link_bw(self):
        return self.hw.ici_bw * self.hw.ici_links

    def a2a_time(self):
        return self.a2a_bytes / self.link_bw

    def ffn_time(self):
        return self.ffn_flops / (self.hw.peak_flops * self.hw.sim_efficiency)

    def attn_time(self):
        return self.attn_flops / (self.hw.peak_flops * self.hw.sim_efficiency)

    def ar_time(self, bytes_=None):
        return (self.grad_bytes if bytes_ is None else bytes_) / self.link_bw


def simulate_backward(m: MoEStepModel, schedule: str = "baseline",
                      n_microops: int = 4, partition_bytes: float = 30e6
                      ) -> dict:
    """Simulate the backward pass of all MoE layers.

    Per layer (backward order): combine-a2a -> expert FFN bwd -> dispatch-
    a2a -> attention bwd compute; the layer's gradient allreduce becomes
    ready after its compute.  Returns step-time components.
    """
    t_net = 0.0      # network stream frontier
    t_cmp = 0.0      # compute stream frontier
    ar_queue: List[float] = []    # pending allreduce bytes (chunks)
    a2a = m.a2a_time()
    ffn = m.ffn_time() * 2.0      # bwd ~ 2x fwd FLOPs
    attn = m.attn_time() * 2.0
    a2a_slow = 0.0
    a2a_total = 0.0

    def drain_ar(until: float, t_net: float) -> float:
        """Work-conserving: run queued AR ops while the network is free
        before `until`.  An op started just before an a2a arrives cannot be
        preempted (§4.1) — whole tensors overshoot badly (Fig. 7b), small
        micro-ops by at most one chunk (Fig. 8a).  That overshoot is the
        entire difference priority-vs-partition measures."""
        while ar_queue and t_net < until:
            dur = ar_queue.pop(0) / m.link_bw
            t_net = t_net + dur
        return t_net

    def chunks_of(nbytes: float) -> List[float]:
        if schedule in ("priority+partition", "priority+partition+pipeline",
                        "baseline-partition"):
            n = max(1, int(round(nbytes / partition_bytes)))
            return [nbytes / n] * n
        return [nbytes]

    bucket_acc = 0.0
    for layer in range(m.n_moe_layers):
        # ---- combine a2a (first a2a of backward) -------------------------
        for direction in (0, 1):
            ready = t_cmp
            if schedule == "baseline":
                # fair share with any pending AR
                pending = sum(ar_queue)
                ar_queue.clear()
                start = max(t_net, ready)
                both = min(pending, m.a2a_bytes)   # overlap portion
                dur = (m.a2a_bytes + both) / m.link_bw  # fair-share slowdown
                t_net = start + dur + max(0.0, (pending - both)) / m.link_bw
                a2a_end = start + dur
            elif schedule == "fixed":
                start = max(t_net, ready)
                a2a_end = start + a2a
                t_net = a2a_end
            else:
                t_net = drain_ar(ready, t_net)
                start = max(t_net, ready)
                a2a_end = start + a2a
                t_net = a2a_end
            a2a_slow += (a2a_end - max(start, ready)) - a2a
            a2a_total += a2a_end - max(start, ready)
            if direction == 0:
                # expert FFN backward between the two a2a ops
                if schedule == "priority+partition+pipeline":
                    # chunked a2a overlaps FFN: critical path a2a + ffn/n
                    t_cmp = a2a_end + ffn / n_microops
                else:
                    t_cmp = a2a_end + ffn
            else:
                t_cmp = max(t_cmp, a2a_end) + attn
        # ---- layer gradients ready -> allreduce --------------------------
        # Lina partitions per-tensor; the baseline/priority modes see DDP
        # bucketing (several layers fused into one large op, §4.1)
        bucket_acc += m.grad_bytes
        flush_bucket = ((layer + 1) % max(m.bucket_layers, 1) == 0
                        or layer == m.n_moe_layers - 1)
        if schedule == "fixed":
            # launch whole bucket now (after second a2a)
            if flush_bucket:
                t_net = max(t_net, t_cmp) + m.ar_time(bucket_acc)
                bucket_acc = 0.0
        elif schedule in ("priority+partition",
                          "priority+partition+pipeline"):
            # tensor partitioning: no bucketing, uniform micro-ops per layer
            ar_queue.extend(chunks_of(m.grad_bytes))
            bucket_acc = 0.0
            t_net = drain_ar(t_cmp, t_net)
        else:
            if flush_bucket:
                ar_queue.append(bucket_acc)
                bucket_acc = 0.0
            if schedule != "baseline":
                t_net = drain_ar(t_cmp, t_net)

    # the embedding gradient lands last (one big bucket)
    if m.embed_grad_bytes:
        ar_queue.extend(chunks_of(m.embed_grad_bytes))

    # flush remaining allreduce (blocks the optimizer step)
    while ar_queue:
        t_net = max(t_net, t_cmp) if t_net < t_cmp else t_net
        t_net += ar_queue.pop(0) / m.link_bw
    step_end = max(t_cmp, t_net)
    return {
        "step_time": step_end,
        "a2a_time_total": a2a_total,
        "a2a_slowdown": a2a_slow,
        "compute_end": t_cmp,
        "net_end": t_net,
    }


def simulate_step(m: MoEStepModel, schedule: str = "baseline",
                  n_microops: int = 4, partition_bytes: float = 30e6) -> dict:
    """Full step = forward (2 a2a + FFN + attention per layer, no
    contention: allreduce only exists in backward) + the simulated backward."""
    a2a = m.a2a_time()
    if schedule == "priority+partition+pipeline":
        ffn_fwd = m.ffn_time() / n_microops   # pipelined behind chunked a2a
    else:
        ffn_fwd = m.ffn_time()
    fwd = m.n_moe_layers * (2 * a2a + ffn_fwd + m.attn_time())
    bwd = simulate_backward(m, schedule, n_microops, partition_bytes)
    return {
        "step_time": fwd + bwd["step_time"],
        "a2a_time_total": bwd["a2a_time_total"] + m.n_moe_layers * 2 * a2a,
        "fwd_time": fwd,
        "bwd": bwd,
    }


def step_model_for(cfg, seq_len: int, global_batch: int, n_devices: int,
                   experts_per_device: int = 1, hw: HardwareConfig = V5E
                   ) -> MoEStepModel:
    """Derive the per-device byte/FLOP counts from a ModelConfig."""
    e = cfg.moe.n_experts
    ep = max(1, e // experts_per_device)
    tokens_dev = global_batch * seq_len / max(n_devices, 1)
    d = cfg.d_model
    f_exp = cfg.moe.d_ff or cfg.d_ff
    ffn_mult = 3 if cfg.ffn_type == "swiglu" else 2
    k = max(cfg.moe.top_k, 1)
    a2a_bytes = tokens_dev * k * d * 2 * (ep - 1) / max(ep, 1)
    ffn_flops = 2 * tokens_dev * k * d * f_exp * ffn_mult
    hd = cfg.resolved_head_dim
    attn_params = 2 * d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
    # per-layer non-MoE compute: projections + S^2 attention (causal) +
    # the model head amortized across layers
    attn_flops = 2 * tokens_dev * attn_params \
        + 2 * 2 * tokens_dev * (seq_len / 2) * cfg.n_heads * hd \
        + 2 * tokens_dev * d * cfg.vocab_size / max(cfg.n_layers, 1)
    # non-expert grads: attention + norms (+ dense FFN layers if interleaved)
    non_expert_per_layer = attn_params + 2 * d
    grad_bytes = non_expert_per_layer * 4  # fp32 gradient allreduce
    return MoEStepModel(
        n_moe_layers=cfg.n_moe_layers,
        a2a_bytes=a2a_bytes,
        ffn_flops=ffn_flops,
        attn_flops=attn_flops,
        grad_bytes=grad_bytes,
        embed_grad_bytes=cfg.vocab_size * d * 4,
        hw=hw,
    )
