"""Chaos benchmark: seeded fault injection over drifting-popularity traces.

Each trace (``drift``, ``flash``) is replayed through three copies of the
full autoscaled serving stack (AdaptiveScheduler + two-phase MoEServer +
continuous-batching engine, the ``autoscale_side`` configuration):

  fault-free       no faults — the recovery reference and the sanity bar;
  degradation-on   the seeded fault schedule fires AND the degradation
                   ladder is engaged: detected device failures are
                   reported (``AdaptiveScheduler.fail_devices`` →
                   route-weight masking, PlanCache device invalidation,
                   device-masked replanning), admission control is armed
                   (bounded queue + deadline shedding + client retry);
  naive            the IDENTICAL fault schedule fires, but failures are
                   never reported, the queue is unbounded and nothing is
                   shed — the stack keeps routing into the dead device.

The schedule per trace is deterministic (seeded): one permanent
single-device failure mid-trace (the headline scenario), an overload
burst, a transient telemetry-corruption window and a planner-crash window
(the latter two exercise the ALWAYS-ON rungs — telemetry validation and
the planner fallback ladder — which protect both variants by design).

Reported per variant:
  * p50/p95 request latency (modeled virtual-clock methodology of
    ``autoscale_side``: measured loads, modeled service time — a dead
    device inflates a step by the token share still routed onto it);
  * the admission ledger: completed / shed(deadline) / shed(rejected),
    and the hard ACCOUNTING INVARIANT offered == completed + shed —
    ``dropped`` (silent losses) must be exactly 0 or the benchmark raises;
  * recovery: steps after the device failure until the rolling p95 of the
    step's FAIL-SLOW MULTIPLIER (modeled service time relative to the
    same step fault-free — the injector logs it per step) re-enters 1.2x
    (None = never recovered).  The multiplier — not request latency — is
    the recovery clock: it is the exact same-batch fault-free
    counterfactual, and it is insensitive to the queueing backlog the
    burst leaves behind (which the admission ledger accounts separately).
    Degradation earns its recovery in this clock only by actually moving
    routed load off the dead device; naive keeps paying ~1 + share *
    (magnitude - 1) forever.

The verdict the chaos suite gates on: degradation-on recovers within the
window and sheds explicitly; naive keeps paying the fail-slow penalty for
the rest of the trace (and recovers late or never).

Full run writes ``BENCH_resilience.json`` (committed); ``--smoke`` writes
``BENCH_resilience.smoke.json`` (gitignored, uploaded by CI, gated on
dropped == 0).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from benchmarks.autoscale_side import (MAX_PACK, N_EXPERTS,
                                       _make_service_model, _skewed_smoke)
from repro.configs import TRANSFORMER_XL, with_experts
from repro.data import DataConfig, SyntheticLM
from repro.resilience import Fault, FaultInjector, FaultSchedule
from repro.runtime.engine import (EngineConfig, ServingEngine, simulate,
                                  summarize_results)
from repro.runtime.server import MoEServer, ServerConfig, profile_from_training
from repro.sched import (AdaptiveScheduler, ControllerConfig, generate_trace,
                         get_spec)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = "BENCH_resilience.json"

FAIL_STEP = 6                 # engine step the device failure fires at
FAIL_DEVICE = 1
FAIL_MAGNITUDE = 8.0          # fail-slow service-time multiplier
RECOVERY_TOL = 1.2            # "recovered" = rolling p95 of the step's
#                               fail-slow multiplier within 20% of 1.0
RECOVERY_WINDOW = 4           # steps per rolling-p95 window


def _fault_schedule(n_steps: int, burst: int) -> FaultSchedule:
    """The per-trace chaos schedule (deterministic, step-keyed)."""
    return FaultSchedule([
        Fault("device_failure", FAIL_STEP, duration=-1, device=FAIL_DEVICE,
              magnitude=FAIL_MAGNITUDE),
        Fault("overload", FAIL_STEP + 2, n_requests=burst),
        Fault("telemetry", FAIL_STEP + 4, duration=3, layer=-1),
        Fault("planner_crash", FAIL_STEP + 6, duration=2),
    ])


def _recovery_steps(penalty_log, fail_step: int):
    """Steps after the failure until the rolling ``RECOVERY_WINDOW``-step
    p95 of the fail-slow multiplier is back within ``RECOVERY_TOL`` of
    1.0 (= the same step fault-free).  Queueing backlog is invisible here
    by construction — this clock measures how long the stack keeps PAYING
    for the dead device, which is the degradation ladder's job to stop.
    None = never recovered."""
    series = [(s, p) for s, p in penalty_log if s >= fail_step]
    for i, (step, _) in enumerate(series):
        window = [p for _, p in series[max(0, i - RECOVERY_WINDOW + 1):i + 1]]
        if float(np.percentile(window, 95)) <= RECOVERY_TOL:
            return max(0, step - fail_step)
    return None


def _run_variant(mode, cfg, full, params, prof, trace, seq, max_new_tokens,
                 schedule, ctrl_kwargs, retry_backoff_s, max_queue,
                 deadline_s):
    """One chaos replay.  ``mode``: fault-free | degradation-on | naive."""
    server = MoEServer(cfg, params, prof,
                       ServerConfig(path_len=3, schedule_policy="lina",
                                    max_pack=MAX_PACK))
    scheduler = AdaptiveScheduler(server, ControllerConfig(**ctrl_kwargs))
    degraded = mode == "degradation-on"
    ecfg = EngineConfig(max_batch_tokens=4 * seq, max_batch_requests=8,
                        max_queue=max_queue if degraded else 0,
                        deadline_s=deadline_s if degraded else 0.0)
    injector = None
    if mode != "fault-free":
        injector = FaultInjector(schedule, resilience=degraded, rng_seed=3,
                                 vocab_size=cfg.vocab_size,
                                 burst_seq_len=seq,
                                 burst_max_new_tokens=max_new_tokens)
    engine = ServingEngine(
        server, ecfg, scheduler=scheduler,
        service_model=_make_service_model(full, server.n_dev,
                                          ecfg.max_batch_tokens,
                                          lina=False, scheduler=scheduler),
        fault_injector=injector)
    t0 = time.perf_counter()
    results = simulate(engine, trace, time_scale=0.0,
                       max_new_tokens=max_new_tokens,
                       retry_backoff_s=retry_backoff_s if degraded else 0.0)
    wall = time.perf_counter() - t0
    m = summarize_results(results, engine=engine)

    offered = len(trace) + (injector.injected if injector else 0)
    shed = len(engine.shed_records)
    dropped = offered - len(results) - shed
    # the chaos suite's hard invariant: degraded means EXPLICITLY shed,
    # never silently lost — in any mode, faulted or not
    if dropped != 0:
        raise AssertionError(
            f"{mode}: {dropped} requests silently dropped "
            f"(offered={offered}, completed={len(results)}, shed={shed})")

    out = {
        "p50_ms": m["latency_p50"] * 1e3, "p95_ms": m["latency_p95"] * 1e3,
        "ttft_p95_ms": m["ttft_p95"] * 1e3,
        "offered": offered, "completed": len(results),
        "shed_deadline": m["shed_deadline"],
        "shed_rejected": m["shed_rejected"],
        "dropped": dropped,
        "wall_us_per_req": wall / max(len(results), 1) * 1e6,
        "degrade_stats": dict(server.degrade_stats),
        "telemetry_errors": dict(scheduler.bus.errors),
        "dead_devices": sorted(server.dead_devices),
    }
    if injector is not None:
        out["faults"] = injector.report()
    return out, injector


def resilience_benchmark(n_requests=48, seq=32, rate_hz=12.0,
                         max_new_tokens=8, profile_batches=4,
                         traces=("drift", "flash"), burst=48,
                         max_queue=24, deadline_s=0.75,
                         retry_backoff_s=0.02, interval=4,
                         json_path: str = JSON_PATH):
    """One row per (trace, variant) + a verdict row per trace; the same
    seeded schedule replays against degradation-on and naive."""
    cfg, params = _skewed_smoke(TRANSFORMER_XL, N_EXPERTS)
    full = with_experts(TRANSFORMER_XL, N_EXPERTS)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=4, seed=1)
    ds = SyntheticLM(dcfg)
    prof = profile_from_training(
        cfg, params, (ds.batch(i) for i in range(profile_batches)),
        path_len=3)
    ctrl_kwargs = dict(interval=interval, hysteresis=0.1, headroom=1.0,
                       min_observations=2)

    rows = []
    jtraces = {}
    for tname in traces:
        spec = get_spec(tname, n_requests=n_requests, seq=seq,
                        rate_hz=rate_hz, seed=7)
        trace = generate_trace(spec, cfg.vocab_size)
        schedule = _fault_schedule(n_steps=n_requests, burst=burst)
        res, injectors = {}, {}
        for mode in ("fault-free", "degradation-on", "naive"):
            r, inj = _run_variant(
                mode, cfg, full, params, prof, trace, seq, max_new_tokens,
                schedule, ctrl_kwargs, retry_backoff_s, max_queue,
                deadline_s)
            res[mode], injectors[mode] = r, inj

        for mode in ("degradation-on", "naive"):
            log = injectors[mode].penalty_log
            rec = _recovery_steps(log, FAIL_STEP)
            res[mode]["recovery_steps"] = rec
            res[mode]["recovered"] = rec is not None
            post = [p for s, p in log if s >= FAIL_STEP]
            res[mode]["post_fault_penalty_p95"] = \
                float(np.percentile(post, 95)) if post else float("nan")
        for mode in ("fault-free", "degradation-on", "naive"):
            r = res[mode]
            extra = ""
            if "recovery_steps" in r:
                extra = (f",recovery_steps={r['recovery_steps']},"
                         f"shed={r['shed_deadline'] + r['shed_rejected']},"
                         f"dropped={r['dropped']}")
            rows.append((
                f"resilience/{tname}-{mode}", r["wall_us_per_req"],
                f"p50_ms={r['p50_ms']:.1f},p95_ms={r['p95_ms']:.1f}{extra}"))

        deg, nai = res["degradation-on"], res["naive"]
        verdict = {
            "no_silent_drops": deg["dropped"] == 0 and nai["dropped"] == 0,
            "degraded_recovers": deg["recovered"],
            "degraded_p95_beats_naive": deg["p95_ms"] < nai["p95_ms"],
            "naive_recovers": nai["recovered"],
        }
        rows.append((f"resilience/{tname}-verdict", 0.0,
                     ",".join(f"{k}={v}" for k, v in verdict.items())))
        jtraces[tname] = {
            "spec": dataclasses.asdict(spec),
            "schedule": [dataclasses.asdict(f) for f in schedule.faults],
            "variants": res,
            "verdict": verdict,
        }

    if not os.path.isabs(json_path):
        json_path = os.path.join(REPO_ROOT, json_path)
    with open(json_path, "w") as fh:
        json.dump({
            "model": f"transformer-xl-{N_EXPERTS}e(smoke)",
            "n_devices": N_EXPERTS,
            "fail_step": FAIL_STEP, "fail_device": FAIL_DEVICE,
            "fail_magnitude": FAIL_MAGNITUDE,
            "recovery_tolerance": RECOVERY_TOL,
            "admission": {"max_queue": max_queue, "deadline_s": deadline_s,
                          "retry_backoff_s": retry_backoff_s},
            "latency_model": "inference_model.InferenceLayerModel@A100_IB "
                             "with fail-slow multiplier on dead/straggler "
                             "token share, time_scale=0",
            "max_new_tokens": max_new_tokens,
            "traces": jtraces,
        }, fh, indent=1)
    rows.append(("resilience/json", 0.0, json_path))
    return rows
