"""Reproduce the paper's Fig. 9 analysis: the cross-layer expert-selection
pattern — how often tokens that picked the same expert at layer i pick the
same (top-k) expert again at layer i+1 — on synthetic patterned streams.

    PYTHONPATH=src python examples/popularity_analysis.py
"""
import numpy as np

from repro.core.popularity import PathProfile, estimation_accuracy


def patterned_stream(n_layers, t, e, strength, seed):
    rng = np.random.RandomState(1234)
    nxt = rng.permutation(e)
    p = 1.0 / (np.arange(e) + 1.0) ** 1.3
    p /= p.sum()
    rng = np.random.RandomState(seed)
    ch = np.zeros((n_layers, t), np.int64)
    ch[0] = rng.choice(e, t, p=p)
    for i in range(1, n_layers):
        follow = rng.rand(t) < strength
        ch[i] = np.where(follow, nxt[ch[i - 1]], rng.choice(e, t, p=p))
    return ch


def fig9_ratio(choices, k=1):
    """Fraction of tokens whose layer-i+1 expert is among the top-k next
    experts of their layer-i group (the paper's Fig. 9 metric)."""
    n_layers, t = choices.shape
    ratios = []
    for i in range(n_layers - 1):
        hit = 0
        for e_id in np.unique(choices[i]):
            grp = choices[i] == e_id
            nxt = choices[i + 1][grp]
            top = np.argsort(-np.bincount(nxt, minlength=nxt.max() + 1))[:k]
            hit += np.isin(nxt, top).sum()
        ratios.append(hit / t)
    return ratios


def main():
    e, t, n_layers = 16, 4096, 12
    for strength in (0.3, 0.5, 0.8):
        ch = patterned_stream(n_layers, t, e, strength, 0)
        r1 = fig9_ratio(ch, 1)
        r2 = fig9_ratio(ch, 2)
        print(f"pattern={strength:.1f}: top-1 ratio "
              f"{np.mean(r1):.2f} top-2 {np.mean(r2):.2f} "
              f"(paper: 0.42 / 0.55)")

    # per-layer estimation accuracy (Fig. 19 shape)
    prof = PathProfile(n_layers=n_layers, n_experts=e, path_len=3)
    for s in range(4):
        prof.profile_batch(patterned_stream(n_layers, t, e, 0.6, s))
    test = patterned_stream(n_layers, t, e, 0.6, 99)
    path = np.zeros((t,), np.int64)
    print("\nlayer  estimation accuracy (top-2 set match)")
    for i in range(n_layers):
        if i >= 3:
            est = prof.estimate_popularity(i, path)
            actual = np.bincount(test[i], minlength=e) / t
            print(f"  {i:3d}   {'yes' if estimation_accuracy(est, actual, 1) else 'no'}")
        path = (path * e + test[i]) % prof.n_buckets


if __name__ == "__main__":
    main()
