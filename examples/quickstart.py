"""Quickstart: the Lina MoE layer, placement planner and popularity
estimator in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import (init_moe_params, moe_layer, plan_placement,
                        PlanArrays, PathProfile)
from repro.core.serving import serve_moe_layer


def main():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=256, n_microops=4,
                    pipeline_ffn=True)
    d_model, tokens = 128, 256
    params = init_moe_params(jax.random.PRNGKey(0), d_model, cfg.d_ff,
                             cfg.n_experts)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, tokens // 4, d_model))

    # --- training-side MoE layer (a2a micro-ops pipelined with the FFN) ---
    out = jax.jit(lambda x, p: moe_layer(None, x, p, cfg, lina=True))(x, params)
    print(f"train MoE: y={out.y.shape} aux_loss={float(out.aux_loss):.4f}")

    # --- inference: estimate popularity, plan placement, serve ------------
    top1 = np.asarray(out.expert_idx[:, 0])
    pop = np.bincount(top1, minlength=cfg.n_experts).astype(np.float64)
    pop /= pop.sum()
    plan = plan_placement(pop, n_devices=cfg.n_experts, max_pack=4)
    print(f"popularity={np.round(pop, 2)}")
    print(f"replicas per expert={plan.n_replicas.tolist()}")
    print(f"device load={np.round(plan.device_load(), 3)} "
          f"(uniform would be {np.round(pop.max(), 3)} max)")

    y, _, _ = jax.jit(lambda x, p, pl: serve_moe_layer(
        None, x, p, cfg, pl, top_k=1))(x.reshape(tokens, d_model), params,
                                       PlanArrays.from_plan(plan))
    print(f"serve MoE (plan-aware dispatch): y={y.shape}")

    # --- sample-path popularity estimation (paper §5.2) -------------------
    prof = PathProfile(n_layers=4, n_experts=cfg.n_experts, path_len=2)
    fake_choices = np.random.RandomState(0).randint(0, 8, (4, tokens))
    prof.profile_batch(fake_choices)
    est = prof.estimate_popularity(2, np.zeros(tokens, np.int64))
    print(f"estimated next-layer popularity: {np.round(est, 3)}")


if __name__ == "__main__":
    main()
