"""End-to-end driver: train a ~100M-parameter MoE transformer for a few
hundred steps with the full production stack — data pipeline, Lina micro-op
schedule, expert-packing controller, checkpointing and restart.

    PYTHONPATH=src python examples/train_moe_100m.py --steps 300

(On this CPU container a step takes ~1s at the default sizes; pass --steps 20
for a quick look.  Kill it mid-run and re-run: it resumes from the latest
checkpoint.)
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig
from repro.data import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

# ~100M params: 12L x d512 (8 experts of 1024 per layer)
MOE_100M = ModelConfig(
    name="moe-100m",
    family="moe",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=32000,
    ffn_type="gelu",
    dtype="float32",
    remat=False,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=1024, n_microops=4),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/moe100m_ckpt")
    args = ap.parse_args()

    cfg = MOE_100M
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active)")
    trainer = Trainer(
        cfg,
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch),
        AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50, lina=True),
    )

    def log(step, m):
        if step % 10 == 0:
            print(f"step {step:4d}  loss {m['loss']:.4f}  "
                  f"aux {m['aux_loss']:.4f}  lr {m['lr']:.2e}", flush=True)

    trainer.run(on_step=log)
    print(f"packing decision: {trainer.packing_decision}")
    print(f"loss: {trainer.metrics_log[0]['loss']:.3f} -> "
          f"{trainer.metrics_log[-1]['loss']:.3f}")
    if trainer.straggler_events:
        print(f"straggler events: {len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
