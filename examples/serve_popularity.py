"""Serving scenario: profile expert-selection paths on 'training' data, then
serve a bursty *generation* trace through the continuous-batching engine —
each request prefills once and then decodes incrementally through its KV
cache, with per-layer plan-scheduled MoE dispatch — and compare Lina's
two-phase popularity scheduling against the uniform (DeepSpeed-style)
placement on latency, TTFT, per-output-token time, load balance, and plan
reuse.

    PYTHONPATH=src python examples/serve_popularity.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, with_experts, TRANSFORMER_XL
from repro.data import DataConfig, SyntheticLM
from repro.models import lm as lm_mod
from repro.runtime.engine import (EngineConfig, ServingEngine, simulate,
                                  summarize_results)
from repro.runtime.server import MoEServer, ServerConfig, profile_from_training


def main():
    cfg = with_experts(TRANSFORMER_XL, 16).smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=16))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))

    # induce inference-style skew (paper Fig. 6): a couple of hot experts
    router = np.array(params.stack.moe.router, np.float32)
    rng = np.random.RandomState(0)
    for i in range(router.shape[0]):
        router[i][:, rng.choice(16, 2, replace=False)] += 2.0
    params = params._replace(stack=params.stack._replace(
        moe=params.stack.moe._replace(router=jnp.asarray(router))))

    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=4))
    print("profiling 4 batches ...")
    prof = profile_from_training(cfg, params,
                                 (ds.batch(i) for i in range(4)), path_len=3)

    # bursty trace: 16 requests, Poisson arrivals at ~25 req/s virtual
    trng = np.random.RandomState(7)
    t, trace = 0.0, []
    for _ in range(16):
        t += trng.exponential(1 / 25.0)
        trace.append((trng.randint(0, cfg.vocab_size, (64,)), t))

    for policy in ("uniform", "lina"):
        srv = MoEServer(cfg, params, prof,
                        ServerConfig(path_len=3, schedule_policy=policy))
        eng = ServingEngine(srv, EngineConfig(max_batch_tokens=256,
                                              max_batch_requests=4))
        results = simulate(eng, trace, max_new_tokens=8)
        m = summarize_results(results)
        loads = [s.device_load.max() for s in eng.layer_stats]
        fts = [s.finetuned for s in eng.layer_stats]
        accs = [s.est_accurate for s in eng.layer_stats]
        print(f"{policy:8s}: p50 {m['latency_p50']*1e3:6.1f} ms  "
              f"p95 {m['latency_p95']*1e3:6.1f} ms  "
              f"TTFT p50 {m['ttft_p50']*1e3:6.1f} ms  "
              f"TPOT p50 {m['tpot_p50']*1e3:6.1f} ms  "
              f"max-device-load {np.mean(loads):.3f} (ideal {1/16:.3f})  "
              f"plan-reuse {eng.plan_reuse_rate:.0%}  "
              f"fine-tune {np.mean(fts):.0%}  "
              f"est-accuracy {np.mean(accs):.0%}")


if __name__ == "__main__":
    main()
