"""Serving scenario: profile expert-selection paths on 'training' data, then
serve batched requests with Lina's two-phase popularity scheduling, and
compare against the uniform (DeepSpeed-style) placement.

    PYTHONPATH=src python examples/serve_popularity.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, with_experts, TRANSFORMER_XL
from repro.data import DataConfig, SyntheticLM
from repro.models import lm as lm_mod
from repro.runtime.server import MoEServer, ServerConfig, profile_from_training


def main():
    cfg = with_experts(TRANSFORMER_XL, 16).smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=16))
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))

    # induce inference-style skew (paper Fig. 6): a couple of hot experts
    router = np.array(params.stack.moe.router, np.float32)
    rng = np.random.RandomState(0)
    for i in range(router.shape[0]):
        router[i][:, rng.choice(16, 2, replace=False)] += 2.0
    params = params._replace(stack=params.stack._replace(
        moe=params.stack.moe._replace(router=jnp.asarray(router))))

    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=4))
    print("profiling 4 batches ...")
    prof = profile_from_training(cfg, params,
                                 (ds.batch(i) for i in range(4)), path_len=3)

    for policy in ("uniform", "lina"):
        srv = MoEServer(cfg, params, prof,
                        ServerConfig(path_len=3, schedule_policy=policy))
        loads, fts, accs = [], [], []
        for b in range(4):
            _, stats = srv.serve(ds.batch(100 + b)["tokens"])
            loads += [s.device_load.max() for s in stats]
            fts += [s.finetuned for s in stats]
            accs += [s.est_accurate for s in stats]
        print(f"{policy:8s}: max-device-load {np.mean(loads):.3f} "
              f"(ideal {1/16:.3f})  fine-tune {np.mean(fts):.0%}  "
              f"est-accuracy {np.mean(accs):.0%}")


if __name__ == "__main__":
    main()
